"""The task-data orchestration interface (paper Fig. 1).

    orchestration(tasks, f, store, write_back=...) -> OrchestrationResult

`tasks` is a vectorized `TaskBatch` (InputPointers = read_keys, OutputPointers
= write_keys, LocalContexts = contexts); `f` is the batched lambda
(contexts, in_values) -> {"update": ..., "result": ...}; `write_back` names a
merge-able ⊕ (Definition 2). The `engine` kwarg selects the scheduling
strategy — "tdorch" (ours) or a §2.3 baseline — without touching user code,
which is the point of the abstraction.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from .baselines import DirectPullEngine, DirectPushEngine, SortBasedEngine
from .datastore import DataStore, TaskBatch
from .engine import OrchestrationResult, TDOrchEngine

ENGINES = {
    "tdorch": TDOrchEngine,
    "push": DirectPushEngine,
    "pull": DirectPullEngine,
    "sort": SortBasedEngine,
}


def make_engine(name: str, num_machines: int, **opts):
    try:
        cls = ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; available: {sorted(ENGINES)}") from None
    return cls(num_machines, **opts)


def orchestration(
    tasks: TaskBatch,
    f: Callable[[np.ndarray, np.ndarray], Dict[str, np.ndarray]],
    store: DataStore,
    write_back: str = "add",
    *,
    engine: str = "tdorch",
    return_results: bool = False,
    **engine_opts,
) -> OrchestrationResult:
    eng = make_engine(engine, store.P, **engine_opts)
    return eng.run_stage(tasks, store, f, write_back=write_back,
                         return_results=return_results)
