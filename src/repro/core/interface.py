"""The task-data orchestration interface (paper Fig. 1).

    orchestration(tasks, f, store, write_back=...) -> OrchestrationResult

`tasks` is a vectorized `TaskBatch` (InputPointers = read_indptr/read_indices
CSR — or the flat `read_keys` convenience for arity-1 batches; OutputPointers
= write_keys; LocalContexts = contexts); `f` is the batched lambda
(contexts, in_values[, mask]) -> {"update": ..., "result": ...}; `write_back`
names a merge-able ⊕ (Definition 2). The `engine` kwarg selects the
scheduling strategy — "tdorch" (ours) or a §2.3 baseline, via the
`@register_engine` registry — without touching user code, which is the point
of the abstraction. `return_results=True` ships each task's per-task result
back to its origin (and is what makes a device backend materialize results
at all); it forwards unchanged to the engine. Session-level options ride the
same call: `backend="numpy" | "jax" | "jax_spmd"` picks the numeric
execution backend — the float64 oracle, the jitted single-device pipeline,
or the mesh-sharded SPMD realization with one device per machine (cost
reports are bit-identical across all three) — `kernel_backend=` picks how
fused-able lambdas (`repro.core.fused_read`) reach the kernel tree on a
device backend ("auto"/"fused" — the ragged-native `stage_fused` kernel;
"interpret" — the same kernel interpreted on CPU; "padded" — the legacy
padded gather) — `replication=` opts into the adaptive hot-chunk
subsystem — and `elasticity=` opts into the elastic-cluster subsystem
(live chunk migration, Phase-3 work stealing, stage-boundary failure
recovery; `repro.core.elasticity`) — all forward to the underlying
`Orchestrator`. `config=` carries every session-level option in one
`SessionConfig` (core/config.py); the per-kwarg spellings remain as a
compatibility shim resolved through the same alias table, and passing a
kwarg that contradicts the config raises.

`orchestration()` is the one-shot shim: it builds a throwaway `Orchestrator`
session per call. Workloads that chain stages (graph rounds, kv batches)
should construct an `Orchestrator` once: `run_stage` chains stages against
one CommForest and an accumulating `SessionReport`, and `run_plan` executes
a declarative multi-round `StagePlan` (task-emitting continuations, fixpoint
loops — see `core/plan.py`) in a single call.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

# importing the engine modules populates the registry
from . import baselines as _baselines  # noqa: F401
from . import engine as _engine  # noqa: F401
from . import policy as _policy  # noqa: F401  (registers engine="auto")
from .config import SessionConfig, resolve_session_config
from .datastore import DataStore, TaskBatch
from .elasticity import (ElasticityConfig, MigrationConfig, RecoveryConfig,
                         StealConfig)
from .engine import OrchestrationResult
from .plan import CARRY, PlanResult, StagePlan
from .registry import ENGINES, make_engine, register_engine
from .session import Orchestrator

__all__ = ["ENGINES", "make_engine", "register_engine", "orchestration",
           "Orchestrator", "StagePlan", "CARRY", "PlanResult",
           "SessionConfig", "resolve_session_config", "ElasticityConfig",
           "MigrationConfig", "StealConfig", "RecoveryConfig"]


def orchestration(
    tasks: TaskBatch,
    f: Callable[[np.ndarray, np.ndarray], Dict[str, np.ndarray]],
    store: DataStore,
    write_back: str = "add",
    *,
    config=None,
    engine: str = None,
    return_results: bool = False,
    backend=None,
    kernel_backend=None,
    replication=None,
    replicate=None,
    elasticity=None,
    **engine_opts,
) -> OrchestrationResult:
    sess = Orchestrator(store, engine=engine, config=config, backend=backend,
                        kernel_backend=kernel_backend,
                        replication=replication, replicate=replicate,
                        elasticity=elasticity, **engine_opts)
    return sess.run_stage(tasks, f, write_back=write_back,
                          return_results=return_results)
