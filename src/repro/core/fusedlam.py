"""Fused-able stage lambdas: a declarative per-pair reduction spec.

A generic user lambda sees the *padded* gathered view — `(n, max_arity, w)`
values plus a validity mask — so the jax backend has no choice but to
materialize that view before calling it. `FusedStageLambda` instead names
its per-pair reduction (`read_op` ∈ add/min/max/first) and an optional
per-row `finish(contexts, reduced)` epilogue, which is exactly the
information the ragged-native fused Pallas kernel
(`kernels/stage_fused/`) needs to walk the CSR pair list directly — no
`max_arity` padding, no materialized intermediates.

The instance is still a perfectly ordinary stage lambda: `__call__`
implements the identical padded-view semantics with numpy (oracle) or jnp
(when handed tracers), so every engine/backend that does NOT understand
`fused_spec` runs it unchanged and bit-compatibly. This module is
deliberately jax-free at import time — `core/__init__.py` re-exports it.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np

FUSED_READ_OPS = ("add", "min", "max", "first")


def _xp(arr):
    """numpy for ndarrays, jax.numpy for tracers/device arrays (lazy)."""
    if isinstance(arr, np.ndarray):
        return np
    import jax.numpy as jnp  # deferred: oracle path never imports jax
    return jnp


class FusedStageLambda:
    """Stage lambda defined by a per-pair reduction + optional epilogue.

    ``read_op`` reduces each task's gathered chunk values across its reads:

    - ``"add"``   — sum of requested values (0 for arity-0 tasks)
    - ``"min"``   — elementwise min (0 for arity-0 tasks, matching the
      zero-filled padded gather the oracle hands generic lambdas)
    - ``"max"``   — elementwise max (0 for arity-0 tasks, as above)
    - ``"first"`` — the task's first requested value (its `primary_read`)

    ``finish(contexts, reduced)`` — optional per-row epilogue applied to the
    `(n, w)` reduced values; must be elementwise per row (no cross-row
    mixing) and written against the array-API subset shared by numpy and
    jax.numpy so both the oracle and the jitted backends can trace it.
    The output is returned as both the stage ``update`` and ``result``.
    """

    def __init__(self, read_op: str, finish: Optional[Callable] = None):
        if read_op not in FUSED_READ_OPS:
            raise ValueError(
                f"read_op {read_op!r} not in {FUSED_READ_OPS}")
        self.read_op = read_op
        self.finish = finish

    @property
    def fused_spec(self) -> Tuple[str, Optional[Callable]]:
        """(read_op, finish) — the backend's routing key to the fused path."""
        return (self.read_op, self.finish)

    def __repr__(self):
        fin = getattr(self.finish, "__name__", self.finish)
        return f"FusedStageLambda({self.read_op!r}, finish={fin})"

    # ---- generic (padded-view) realization --------------------------------
    def reduce_padded(self, vals, mask):
        """Reduce the padded gathered view exactly like the fused kernel
        reduces the CSR pair list. `vals` is `(n, w)` (arity ≤ 1, `mask`
        `(n,)`) or `(n, A, w)` (ragged, `mask` `(n, A)`)."""
        xp = _xp(vals)
        if vals.ndim == 2:  # arity-≤1 view: every op degenerates to masking
            return xp.where(mask[:, None], vals, xp.zeros((), vals.dtype))
        if self.read_op == "add":
            return xp.where(mask[..., None], vals,
                            xp.zeros((), vals.dtype)).sum(axis=1)
        if self.read_op == "first":
            return xp.where(mask[:, :1], vals[:, 0, :],
                            xp.zeros((), vals.dtype))
        big = xp.asarray(np.finfo(np.float32).max / 2, dtype=vals.dtype)
        filled = xp.where(mask[..., None], vals, big if self.read_op == "min"
                          else -big)
        red = filled.min(axis=1) if self.read_op == "min" \
            else filled.max(axis=1)
        # arity-0 rows reduce to 0, matching the oracle's zero-filled gather
        return xp.where(mask.any(axis=1)[:, None], red,
                        xp.zeros((), vals.dtype))

    def __call__(self, contexts, vals, mask) -> Dict[str, object]:
        out = self.reduce_padded(vals, mask)
        if self.finish is not None:
            out = self.finish(contexts, out)
        return {"update": out, "result": out}


_FUSED_CACHE: Dict[Tuple[str, int], FusedStageLambda] = {}


def fused_read(read_op: str, finish: Optional[Callable] = None
               ) -> FusedStageLambda:
    """A cached `FusedStageLambda` — reusing the instance keeps the
    backends' per-lambda jit caches warm across stages/sessions."""
    key = (read_op, id(finish))
    lam = _FUSED_CACHE.get(key)
    if lam is None or lam.finish is not finish:
        lam = FusedStageLambda(read_op, finish)
        _FUSED_CACHE[key] = lam
    return lam
