"""Engine registry: the pluggable scheduling strategies behind the
orchestration interface.

Engines self-register with `@register_engine("name")`, so adding a strategy
is one decorator away — no central table to edit. An engine class takes
`(num_machines, **opts)` and exposes
`run_stage(tasks, store, f, write_back=..., return_results=...)`.
"""
from __future__ import annotations

from typing import Callable, Dict, Type

ENGINES: Dict[str, type] = {}


def register_engine(name: str) -> Callable[[type], type]:
    """Class decorator registering an orchestration engine under `name`."""

    def deco(cls: type) -> type:
        if name in ENGINES and ENGINES[name] is not cls:
            raise ValueError(f"engine {name!r} already registered "
                             f"({ENGINES[name].__name__})")
        ENGINES[name] = cls
        return cls

    return deco


def get_engine_cls(name: str) -> Type:
    try:
        return ENGINES[name]
    except KeyError:
        raise KeyError(
            f"unknown engine {name!r}; available: {sorted(ENGINES)}") from None


def make_engine(name: str, num_machines: int, **opts):
    return get_engine_cls(name)(num_machines, **opts)
