"""Engine + execution-backend registries: the two pluggable axes behind the
orchestration interface.

Engines self-register with `@register_engine("name")`, so adding a strategy
is one decorator away — no central table to edit. An engine class takes
`(num_machines, **opts)` and exposes
`run_stage(tasks, store, f, write_back=..., return_results=...)`.

Execution backends (`@register_backend`) are orthogonal to engines: an
engine decides *where* tasks run and *what the wire carries* (the cost
model); a backend decides *how the numeric work is executed* — the pure
numpy reference pass, or the jit-compiled JAX pipeline that dispatches to
the Pallas kernels. Every engine takes `backend=` and charges identical
costs on either one (the backend-parity contract in `core/backend.py`).
"""
from __future__ import annotations

from typing import Callable, Dict, Type

ENGINES: Dict[str, type] = {}
BACKENDS: Dict[str, type] = {}


def register_engine(name: str) -> Callable[[type], type]:
    """Class decorator registering an orchestration engine under `name`."""

    def deco(cls: type) -> type:
        if name in ENGINES and ENGINES[name] is not cls:
            raise ValueError(f"engine {name!r} already registered "
                             f"({ENGINES[name].__name__})")
        ENGINES[name] = cls
        return cls

    return deco


def get_engine_cls(name: str) -> Type:
    try:
        return ENGINES[name]
    except KeyError:
        raise KeyError(
            f"unknown engine {name!r}; available: {sorted(ENGINES)}") from None


def make_engine(name: str, num_machines: int, **opts):
    return get_engine_cls(name)(num_machines, **opts)


def register_backend(name: str) -> Callable[[type], type]:
    """Class decorator registering an execution backend under `name`."""

    def deco(cls: type) -> type:
        if name in BACKENDS and BACKENDS[name] is not cls:
            raise ValueError(f"backend {name!r} already registered "
                             f"({BACKENDS[name].__name__})")
        BACKENDS[name] = cls
        return cls

    return deco


def get_backend_cls(name: str) -> Type:
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; available: {sorted(BACKENDS)}") from None
