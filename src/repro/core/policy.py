"""Cost-model-driven per-stage engine selection — `engine="auto"` (§4).

The paper's central claim is that TD-Orch *adapts*: Phase-1 contention
detection tells the orchestrator how demand is distributed, and the
orchestrator — not the caller — decides whether tasks should push to their
data, pull their data in, or ride the forest. This module closes that loop
for the reproduction. Until now the caller picked one of the four registered
engines per session; `engine="auto"` makes the session pick per stage, from
the same word-counting rules the engines already charge:

  * every engine exposes `estimate_cost(histogram, layout) ->
    PhaseCostEstimate` — an analytic replay of its own charging paths
    against the stage's `StageLayout` (task batch, store placement, replica
    directory, result/update widths). The estimate is bit-identical to the
    realized stage report whenever the layout's documented assumptions hold
    (lambda returns `update_width`-wide updates for every declared write
    key, `result_width`-wide results when requested, no work stealing);
  * `StagePolicy` picks the argmin engine under a configurable objective
    (total words by default; a BSP `max_comm + L·rounds` objective for
    latency-bound stages), with hysteresis so fixpoint loops don't thrash
    between engines whose bills are within noise of each other;
  * `AutoEngine` (registered as `"auto"`) wires the two into the ordinary
    engine interface, so every front door that resolves engines through
    `SessionConfig` — `orchestration()`, `Orchestrator`/`GraphSession`,
    `run_plan` rounds, `DistributedHashTable`, `serve.Frontend`, the
    paramserve tier — gets the adaptive loop by spelling `engine="auto"`.

Decisions are deterministic and backend-independent: the demand histogram
is a plain `np.bincount` of the batch's requested keys, and the only
backend call the estimators make (`argsort_stable`, for sort's run
placement) is parity-pinned across numpy/jax/jax_spmd. Each decision is
recorded on the session's `SessionReport.policy_decisions` (chosen engine,
per-candidate predicted bills, predicted vs. realized words), and the cost
of *deciding* — per-machine demand sketches to a coordinator plus the
decision broadcast — is charged under the dedicated `policy` phase
(`cost.POLICY_PHASE`), so parity tests can compare an auto stage against
the chosen fixed engine with `assert_cost_parity(..., ignore=("policy",))`.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Dict, Optional, Tuple

import numpy as np

from .backend import make_backend
from .baselines import DirectPullEngine, DirectPushEngine, SortBasedEngine
from .cost import POLICY_PHASE, CostAccumulator, StageReport
from .datastore import DataStore, TaskBatch
from .engine import TDOrchEngine
from .registry import register_engine
from .replication import ReplicaSet

__all__ = [
    "StageLayout", "PhaseCostEstimate", "PolicyConfig", "PolicyDecision",
    "StagePolicy", "AutoEngine", "make_policy_config", "decision_phase",
    "POLICY_PHASE",
]


@dataclasses.dataclass(frozen=True)
class StageLayout:
    """The cost-relevant projection of one stage, handed to estimators.

    Holds *references* to the live batch/store/directory (estimators replay
    charging formulas against them; nothing is copied or mutated) plus the
    width assumptions that stand in for the not-yet-executed lambda:

    sigma           context words per task (σ) — `tasks.ctx_words`.
    update_width    words per ⊗-combined update row the lambda will return
                    (`store.value_width` unless overridden).
    result_width    words per result row when `return_results` is set.
    assume_updates  whether the lambda returns updates at all — defaults to
                    "it writes iff the batch declares write keys".

    These assumptions are the estimator's documented tolerance: a lambda
    returning wider/narrower rows (e.g. a ragged reduce emitting
    `(n, max_arity·w)` results) realizes a bill that differs from the
    estimate exactly by the width delta on the affected sends.
    """

    tasks: TaskBatch
    store: DataStore
    replicas: Optional[ReplicaSet] = None
    return_results: bool = False
    sigma: int = 0
    update_width: int = 1
    result_width: int = 1
    assume_updates: bool = False

    @staticmethod
    def capture(tasks: TaskBatch, store: DataStore, *, replicas=None,
                return_results: bool = False, update_width=None,
                result_width=None, assume_updates=None) -> "StageLayout":
        w = store.value_width
        return StageLayout(
            tasks=tasks, store=store, replicas=replicas,
            return_results=bool(return_results),
            sigma=int(tasks.ctx_words),
            update_width=int(w if update_width is None else update_width),
            result_width=int(w if result_width is None else result_width),
            assume_updates=bool((tasks.write_keys >= 0).any()
                                if assume_updates is None else assume_updates),
        )


@dataclasses.dataclass(frozen=True)
class PhaseCostEstimate:
    """One engine's predicted bill for one stage: a full per-phase
    `StageReport` produced by replaying the engine's charging paths, so a
    conformance test can pin prediction against realization with
    `assert_cost_parity` — not just compare scalars."""

    engine: str
    report: StageReport

    @property
    def total_words(self) -> float:
        return float(self.report.sent.sum())

    @property
    def max_comm(self) -> float:
        return self.report.comm_time

    @property
    def rounds(self) -> int:
        return self.report.rounds

    @property
    def max_compute(self) -> float:
        return self.report.compute_time

    def objective_value(self, objective: str = "total_words",
                        round_latency: float = 0.0) -> float:
        """The scalar the policy minimizes. "total_words" — network volume
        (the §4 comparison metric); "bsp" — `max_comm + L·rounds`, the
        Appendix-A BSP time with per-round latency L (what separates a
        1-round broadcast from a log-depth tree when their volumes tie)."""
        if objective == "total_words":
            return self.total_words
        if objective == "bsp":
            return self.max_comm + round_latency * self.rounds
        raise ValueError(f"unknown policy objective {objective!r} "
                         f"(known: 'total_words', 'bsp')")


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Knobs of the stage policy (all deterministic).

    candidates      engine names considered, in tie-break priority order.
    objective       "total_words" (default) or "bsp" — see
                    `PhaseCostEstimate.objective_value`.
    round_latency   L of the "bsp" objective; ignored for "total_words".
    hysteresis      the incumbent engine is kept unless a challenger's
                    predicted bill beats it by MORE than this fraction —
                    fixpoint loops whose per-round bills jitter across the
                    decision boundary then stop thrashing. 0.05 keeps the
                    worst-case realized bill within 1/(1-0.05) ≈ 1.053x of
                    the per-stage argmin, comfortably inside the 1.1x gate
                    `tests/test_policy.py` enforces.
    sketch_words    words each active machine sends the coordinator per
                    decision (its demand-histogram sketch).
    decision_words  words the coordinator broadcasts back (chosen engine +
                    epoch). Both are charged under the `policy` phase.
    """

    candidates: Tuple[str, ...] = ("tdorch", "pull", "push", "sort")
    objective: str = "total_words"
    round_latency: float = 0.0
    hysteresis: float = 0.05
    sketch_words: float = 4.0
    decision_words: float = 2.0


def make_policy_config(spec) -> PolicyConfig:
    """None → defaults; dict → kwargs; PolicyConfig → itself."""
    if spec is None:
        return PolicyConfig()
    if isinstance(spec, PolicyConfig):
        return spec
    if isinstance(spec, dict):
        spec = dict(spec)
        if "candidates" in spec:
            spec["candidates"] = tuple(spec["candidates"])
        return PolicyConfig(**spec)
    raise TypeError(f"policy= must be None, a dict, or a PolicyConfig, "
                    f"got {type(spec).__name__}")


@dataclasses.dataclass
class PolicyDecision:
    """One recorded stage decision (`SessionReport.policy_decisions`).

    choice           candidate the policy selected ("tdorch"/"pull"/... for
                     engine decisions; "sparse"/"dense" for the graph
                     session's edge-map mode decisions).
    predicted        per-candidate objective values the choice was made on.
    predicted_words  the chosen candidate's predicted total words.
    realized_words   the stage's realized total words (policy phase
                     excluded), filled after the stage runs.
    policy_words     decision-latency words charged under the `policy` phase.
    incumbent        previous stage's choice (None on the first decision).
    switched         whether this decision changed engines.
    kind             "engine" | "edge_map_mode".
    """

    choice: str
    predicted: Dict[str, float]
    predicted_words: float
    realized_words: float = float("nan")
    policy_words: float = 0.0
    objective: str = "total_words"
    incumbent: Optional[str] = None
    switched: bool = False
    stage_index: int = -1
    kind: str = "engine"
    estimate: Optional[PhaseCostEstimate] = None

    @property
    def engine(self) -> str:
        return self.choice


class StagePolicy:
    """Deterministic argmin-with-hysteresis chooser over candidate bills.

    Stateful: remembers the incumbent across stages (one policy per
    session-lived `AutoEngine`), which is exactly the memory hysteresis
    needs. Ties break by `candidates` order, so decisions are
    bit-reproducible across runs and — because every estimator input is
    parity-pinned — across backends.
    """

    def __init__(self, config: PolicyConfig | None = None):
        self.config = make_policy_config(config)
        self.incumbent: Optional[str] = None

    def choose(self, estimates: Dict[str, PhaseCostEstimate],
               kind: str = "engine") -> PolicyDecision:
        cfg = self.config
        order = [nm for nm in cfg.candidates if nm in estimates]
        if not order:
            raise ValueError(
                f"no candidate estimates: have {sorted(estimates)}, "
                f"policy considers {cfg.candidates}")
        vals = {nm: float(estimates[nm].objective_value(cfg.objective,
                                                        cfg.round_latency))
                for nm in order}
        best = min(order, key=vals.__getitem__)  # stable: first-in-order tie
        choice = best
        inc = self.incumbent
        if inc is not None and inc in vals \
                and vals[best] >= vals[inc] * (1.0 - cfg.hysteresis):
            choice = inc  # challenger not decisively better — don't thrash
        decision = PolicyDecision(
            choice=choice, predicted=vals,
            predicted_words=float(estimates[choice].total_words),
            objective=cfg.objective, incumbent=inc,
            switched=(inc is not None and choice != inc),
            kind=kind, estimate=estimates[choice])
        self.incumbent = choice
        return decision


def decision_phase(P: int, active_machines: np.ndarray,
                   config: PolicyConfig) -> StageReport:
    """The bill for *making* a decision, as its own one-phase report:
    every machine with tasks this stage sends its `sketch_words` demand
    sketch to the coordinator (machine 0), which runs the argmin (one work
    unit) and broadcasts the `decision_words` verdict to all P machines —
    two BSP rounds. Self-sends (the coordinator's own rows) are free, as
    everywhere in the cost model."""
    cost = CostAccumulator(P)
    cost.begin(POLICY_PHASE)
    active = np.asarray(active_machines, dtype=np.int64).ravel()
    if active.size:
        cost.send(active, np.zeros(active.size, dtype=np.int64),
                  config.sketch_words)
        cost.work(np.zeros(1, dtype=np.int64), 1.0)
        cost.send(np.zeros(P, dtype=np.int64), np.arange(P, dtype=np.int64),
                  config.decision_words)
        cost.tick(2)
    cost.end()
    return cost.totals()


@register_engine("auto")
class AutoEngine:
    """The adaptive orchestrator: per stage, estimate every candidate
    engine's bill from the demand histogram and the stage layout, pick the
    argmin (with hysteresis), charge the decision under the `policy` phase,
    and delegate the stage to the winner.

    Drop-in at every front door: registered under `"auto"` in the engine
    registry, so `engine="auto"` (or `SessionConfig(engine="auto")`) works
    anywhere a fixed engine name does. The four sub-engines share one
    numeric backend instance — device caches, forest plans, and the
    execute→apply carry behave exactly as a fixed-engine session's.
    """

    def __init__(self, num_machines: int, *, fanout=None, C=None, sigma=None,
                 work_per_task: float = 1.0, work_per_pair: float = 0.0,
                 backend=None, policy=None):
        self.P = int(num_machines)
        self.backend = make_backend(backend)
        self.policy = StagePolicy(make_policy_config(policy))
        common = dict(work_per_task=work_per_task,
                      work_per_pair=work_per_pair, backend=self.backend)
        builders = {
            "tdorch": lambda: TDOrchEngine(self.P, fanout=fanout, C=C,
                                           sigma=sigma, **common),
            "pull": lambda: DirectPullEngine(self.P, **common),
            "push": lambda: DirectPushEngine(self.P, **common),
            "sort": lambda: SortBasedEngine(self.P, **common),
        }
        unknown = [nm for nm in self.policy.config.candidates
                   if nm not in builders]
        if unknown:
            raise ValueError(f"auto policy candidates {unknown} are not "
                             f"estimable engines (known: {sorted(builders)})")
        self.engines = {nm: builders[nm]()
                        for nm in self.policy.config.candidates}
        # sessions reach the forest through the engine; expose tdorch's
        tdorch = self.engines.get("tdorch")
        self.forest = getattr(tdorch, "forest", None)

    # ------------------------------------------------------------------
    def run_stage(self, tasks, store, f, write_back="add",
                  return_results=False, replicas=None, stealer=None):
        layout = StageLayout.capture(tasks, store, replicas=replicas,
                                     return_results=return_results)
        # Phase-1 demand histogram, decision input — plain numpy bincount so
        # the decision is bit-reproducible across runs and backends
        if tasks.nnz:
            histogram = np.bincount(tasks.read_indices,
                                    minlength=store.num_keys)
        else:
            histogram = np.zeros(store.num_keys, dtype=np.int64)
        estimates = {nm: eng.estimate_cost(histogram, layout)
                     for nm, eng in self.engines.items()}
        decision = self.policy.choose(estimates)
        policy_report = decision_phase(
            self.P, np.unique(tasks.origin), self.policy.config)
        decision.policy_words = float(policy_report.sent.sum())
        engine = self.engines[decision.choice]
        extra = {}
        if stealer is not None and "stealer" in inspect.signature(
                engine.run_stage).parameters:
            extra["stealer"] = stealer
        res = engine.run_stage(tasks, store, f, write_back=write_back,
                               return_results=return_results,
                               replicas=replicas, **extra)
        decision.realized_words = float(res.report.sent.sum())
        # the decision bill rides this stage's report as its own phase
        res.report = StageReport(res.report.P,
                                 policy_report.phases + res.report.phases)
        res.decision = decision
        return res
