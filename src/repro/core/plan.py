"""Declarative StagePlan dataflow programs — multi-round orchestration as data.

`run_stage` executes ONE stage and hands control back to user code, so every
multi-round workload (the five §5 TDO-GP algorithms, YCSB read-modify-write
chains, embedding refresh) ends up hand-rolling its own Python driver loop
with a host synchronization after every stage. A `StagePlan` lifts that loop
into the framework: the application declares *what* each stage needs (tasks +
data pointers, exactly the paper's Fig. 1 contract) plus how each stage
**emits continuation tasks**, and the session owns *how* rounds execute —
reusing the CommForest and replica directory across rounds and (on
``backend="jax"``) keeping store/state arrays device-resident with at most
one host sync per round.

Builder combinators (each returns the plan, so they chain)::

    plan = StagePlan("chase")
    plan.loop(
        StagePlan().stage(CARRY, f, "write", emit=next_hop,
                          return_results=True),
        until="empty", max_rounds=8)
    out = sess.run_plan(plan, carry=first_batch)

* ``plan.stage(tasks, f, write_back, emit=..., **opts)`` — one orchestration
  stage run through ``session.run_stage``. `tasks` is a `TaskBatch`, the
  `CARRY` sentinel (consume the loop's carried emission), or a factory
  ``state -> TaskBatch`` rebuilt per round. The **emission contract**: after
  the stage executes, ``emit(state, result)`` produces the next round's
  `TaskBatch` *inside the framework* (return None to emit nothing); the
  framework threads it into ``state.carry``.
* ``plan.edge_map(frontier, f, write_back, merge_value, ...)`` — one
  DistEdgeMap round run through ``session.edge_map`` (GraphSession plans).
  Its emission is implicit — the returned next frontier — unless ``emit=``
  post-processes it.
* ``plan.host(fn)`` — a host-side step between stages (e.g. preparing the
  backward pass of BC). Like every user callback, it observes flushed,
  up-to-date host store values.
* ``plan.loop(body, until="empty" | <predicate>, max_rounds=k)`` — the
  fixpoint combinator. ``until="empty"`` stops *before* a round whose carried
  emission is empty (frontier-driven algorithms); a callable ``until`` is a
  convergence predicate evaluated *after* each round (PageRank's delta);
  ``max_rounds`` (int, or ``state -> int`` resolved at loop entry) bounds the
  round count. `body` is a sub-plan, or a factory ``state -> sub-plan`` for
  bodies whose lambdas close over per-round values.

Execution (`sess.run_plan(plan, carry=..., state=...)`) drives the whole
program against ONE session, so per-phase cost reports are **bit-identical**
to the equivalent hand-rolled `run_stage`/`edge_map` loop (pinned by
`tests/test_plan.py`): the plan runner calls exactly the same session entry
points in exactly the same order. What changes is the execution *policy* the
framework may now apply: on the jax backend, `Orchestrator.run_plan` opens a
plan scope in which write-backs stay device-resident (the host store copy is
refreshed lazily — always *before* any user callback runs, and once at plan
exit) and task batches are padded to bucketed static shapes so rounds with
drifting batch sizes reuse compiled executables instead of re-jitting. The
mesh-sharded backend (``backend="jax_spmd"``) runs plans too: its per-shard
slabs stay device-resident across rounds (owner shards ⊙-apply in place),
per-shard batch shapes use the same pow2 bucketing against re-jitting, and
the authoritative host copy catches up with one gather of the written rows
per stage — so user callbacks always observe fresh host state without a
flush scope.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional


class _Carry:
    """Sentinel: "this stage consumes the loop's carried emission"."""

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "CARRY"


CARRY = _Carry()


def _carry_is_empty(carry) -> bool:
    """Duck-typed emptiness: None, an empty TaskBatch (n == 0), an empty
    DistVertexSubset (is_empty), or any empty sized container."""
    if carry is None:
        return True
    if hasattr(carry, "is_empty"):
        return bool(carry.is_empty)
    n = getattr(carry, "n", None)
    if n is not None:
        return int(n) == 0
    try:
        return len(carry) == 0
    except TypeError:
        return False


class PlanState:
    """Mutable state threaded through a plan run.

    * ``state.carry`` — the current continuation payload (a `TaskBatch`
      emitted by the previous stage, or a `DistVertexSubset` frontier).
    * ``state.round`` — rounds completed so far in the innermost active loop
      (0 inside the first round's factories).
    * ``state["name"]`` — user slots (dict-style), e.g. PageRank's rank
      vector or BC's recorded frontiers.
    """

    def __init__(self, data: Optional[Dict[str, Any]] = None):
        self.carry: Any = None
        self.round: int = 0
        self.data: Dict[str, Any] = dict(data or {})

    def __getitem__(self, key: str) -> Any:
        return self.data[key]

    def __setitem__(self, key: str, value: Any) -> None:
        self.data[key] = value

    def __contains__(self, key: str) -> bool:
        return key in self.data

    def get(self, key: str, default: Any = None) -> Any:
        return self.data.get(key, default)


@dataclasses.dataclass
class StageRecord:
    """One executed plan op: `kind` is "stage" (result: OrchestrationResult),
    "edge_map" (result: EdgeMapStats), or "host" (result: the callback's
    return value); `round` is the loop round it ran in (-1 = top level)."""

    kind: str
    name: str
    round: int
    result: Any


@dataclasses.dataclass
class LoopRecord:
    """One completed loop: how many rounds ran and why it stopped
    ("empty" — carried emission drained; "until" — predicate satisfied;
    "max_rounds" — round bound hit)."""

    name: str
    rounds: int
    reason: str


@dataclasses.dataclass
class PlanResult:
    """What `run_plan` returns. Cost lives on the session's report (exactly
    as it would for a hand-rolled loop); this carries the program-level
    outcome: per-op records, per-loop round counts/stop reasons, and the
    final `PlanState`."""

    records: List[StageRecord]
    loops: List[LoopRecord]
    state: PlanState

    @property
    def rounds(self) -> int:
        """Total loop rounds executed (summed over the plan's loops)."""
        return sum(lp.rounds for lp in self.loops)

    @property
    def stats(self) -> List[Any]:
        """EdgeMapStats of every edge-map op, in execution order."""
        return [r.result for r in self.records if r.kind == "edge_map"]

    @property
    def results(self) -> List[Any]:
        """OrchestrationResults of every task stage, in execution order."""
        return [r.result for r in self.records if r.kind == "stage"]


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _StageOp:
    kind = "stage"
    tasks: Any  # TaskBatch | CARRY | callable(state) -> TaskBatch | None
    f: Callable
    write_back: Any
    emit: Optional[Callable]
    name: str
    opts: Dict[str, Any]

    def run(self, rn: "_PlanRunner", state: PlanState, round_idx: int) -> None:
        tasks = self.tasks
        if isinstance(tasks, _Carry):
            tasks = state.carry
        elif callable(tasks):
            tasks = rn.user(tasks, state)
        if tasks is None:
            raise ValueError(
                f"plan stage {self.name!r} has no tasks to run: its CARRY/"
                "factory resolved to None. Frontier-driven stages belong in "
                "a loop(until='empty') so the plan stops before an empty "
                "round.")
        res = rn.sess.run_stage(tasks, self.f, write_back=self.write_back,
                                **self.opts)
        rn.records.append(StageRecord("stage", self.name, round_idx, res))
        if self.emit is not None:
            state.carry = rn.user(self.emit, state, res)
            rn.carry_touched = True


@dataclasses.dataclass
class _EdgeMapOp:
    kind = "edge_map"
    frontier: Any  # DistVertexSubset | CARRY | callable(state) -> subset
    f: Callable
    write_back: Callable
    merge_value: str
    filter_dst: Optional[Callable]
    emit: Optional[Callable]
    name: str
    opts: Dict[str, Any]

    def run(self, rn: "_PlanRunner", state: PlanState, round_idx: int) -> None:
        fr = self.frontier
        if isinstance(fr, _Carry):
            fr = state.carry
        elif callable(fr):
            fr = rn.user(fr, state)
        if fr is None:
            raise ValueError(
                f"plan edge_map {self.name!r} has no frontier: its CARRY/"
                "factory resolved to None. Frontier-driven rounds belong in "
                "a loop(until='empty').")
        nxt, st = rn.sess.edge_map(fr, self.f, self.write_back,
                                   self.merge_value, self.filter_dst,
                                   **self.opts)
        rn.records.append(StageRecord("edge_map", self.name, round_idx, st))
        state.carry = nxt if self.emit is None else rn.user(self.emit, state,
                                                            nxt)
        rn.carry_touched = True


@dataclasses.dataclass
class _HostOp:
    kind = "host"
    fn: Callable
    name: str

    def run(self, rn: "_PlanRunner", state: PlanState, round_idx: int) -> None:
        out = rn.user(self.fn, state)
        rn.records.append(StageRecord("host", self.name, round_idx, out))


@dataclasses.dataclass
class _LoopOp:
    kind = "loop"
    body: Any  # StagePlan | single op | callable(state) -> either
    until: Any  # "empty" | callable(state) -> bool | None
    max_rounds: Any  # int | callable(state) -> int | None
    name: str

    def run(self, rn: "_PlanRunner", state: PlanState, round_idx: int) -> None:
        max_r = self.max_rounds
        if max_r is not None and callable(max_r):
            max_r = int(rn.user(max_r, state))
        outer_round = state.round
        state.round = rounds = 0
        reason = "max_rounds"
        while True:
            if self.until == "empty" and _carry_is_empty(state.carry):
                reason = "empty"
                break
            if max_r is not None and rounds >= max_r:
                reason = "max_rounds"
                break
            body = self.body
            if callable(body) and not isinstance(body, StagePlan):
                body = rn.user(body, state)
            rn.carry_touched = False
            rn.run_ops(_as_ops(body), state, rounds)
            if self.until == "empty" and not rn.carry_touched:
                # no op in the body emitted a continuation, so the carried
                # batch can never drain — re-running it forever is always a
                # bug; fail loudly instead of hanging
                raise RuntimeError(
                    f"loop {self.name!r} (until='empty') made no progress: "
                    "no stage in the body has emit= and no edge_map round "
                    "ran, so the carried emission can never become empty. "
                    "Add an emit= continuation, or use until=None with "
                    "max_rounds= for a fixed-round loop.")
            rounds += 1
            state.round = rounds
            if callable(self.until) and rn.user(self.until, state):
                reason = "until"
                break
        rn.loops.append(LoopRecord(self.name, rounds, reason))
        state.round = outer_round


def _as_ops(body) -> List[Any]:
    if isinstance(body, StagePlan):
        return body._ops
    if hasattr(body, "run") and hasattr(body, "kind"):
        return [body]
    raise TypeError(
        f"a loop body must be a StagePlan (or a factory returning one), "
        f"got {type(body).__name__}")


# ---------------------------------------------------------------------------
# the builder
# ---------------------------------------------------------------------------
class StagePlan:
    """An ordered dataflow program over one session (see module docstring).

    Combinators return ``self`` so plans read as chained declarations. A plan
    is inert data until handed to ``Orchestrator.run_plan`` /
    ``GraphSession.run_plan`` (or another session exposing the same entry
    points); the same plan object may be re-run.
    """

    def __init__(self, name: str = "plan"):
        self.name = name
        self._ops: List[Any] = []

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        kinds = ",".join(op.kind for op in self._ops)
        return f"StagePlan({self.name!r}: [{kinds}])"

    @property
    def num_ops(self) -> int:
        return len(self._ops)

    # -- combinators -------------------------------------------------------
    def stage(self, tasks, f, write_back="add", *, emit=None, name=None,
              **opts) -> "StagePlan":
        """Append one orchestration stage (``session.run_stage``).

        `tasks`: a `TaskBatch`, `CARRY`, or a factory ``state -> TaskBatch``.
        `emit`: ``(state, OrchestrationResult) -> TaskBatch | None`` — the
        continuation contract; the return value becomes ``state.carry``.
        Extra ``opts`` (e.g. ``return_results=True``) forward to
        ``run_stage`` unchanged.
        """
        self._ops.append(_StageOp(tasks, f, write_back, emit,
                                  name or f"stage{len(self._ops)}", opts))
        return self

    def edge_map(self, frontier, f, write_back, merge_value="min", *,
                 filter_dst=None, emit=None, name=None, **opts) -> "StagePlan":
        """Append one DistEdgeMap round (``session.edge_map``). The next
        frontier it returns is the implicit emission; ``emit(state, nxt)``
        may observe/replace it. Extra ``opts`` (``force_mode=``,
        ``account=``, ...) forward to ``edge_map`` unchanged."""
        self._ops.append(_EdgeMapOp(frontier, f, write_back, merge_value,
                                    filter_dst, emit,
                                    name or f"edge_map{len(self._ops)}", opts))
        return self

    def host(self, fn, *, name=None) -> "StagePlan":
        """Append a host-side step ``fn(state)`` between stages. Runs with
        host store values flushed/up-to-date (device-resident plan scopes
        synchronize before it)."""
        self._ops.append(_HostOp(fn, name or f"host{len(self._ops)}"))
        return self

    def loop(self, body, *, until="empty", max_rounds=None,
             name=None) -> "StagePlan":
        """Append a fixpoint loop over `body` (a sub-plan, or a factory
        ``state -> sub-plan``). ``until="empty"`` re-checks the carried
        emission before every round; a callable ``until`` is evaluated after
        each round; ``max_rounds`` (int or ``state -> int``, resolved at loop
        entry) caps the rounds. At least one stopping rule is required."""
        if until is None and max_rounds is None:
            raise ValueError(
                "loop() needs a stopping rule: until='empty', a callable "
                "until-predicate, and/or max_rounds=")
        if until is not None and until != "empty" and not callable(until):
            raise ValueError(
                f"until must be 'empty', a callable predicate, or None — "
                f"got {until!r}")
        self._ops.append(_LoopOp(body, until, max_rounds,
                                 name or f"loop{len(self._ops)}"))
        return self


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------
class _PlanRunner:
    def __init__(self, sess):
        self.sess = sess
        self.backend = getattr(sess, "backend", None)
        self.records: List[StageRecord] = []
        self.loops: List[LoopRecord] = []
        # set by emitting ops; loops use it to detect no-progress rounds
        self.carry_touched = False

    def user(self, fn: Callable, *args):
        """Invoke a user callback (task/body factory, emit, until predicate,
        host step) with host state guaranteed fresh: a device-resident plan
        scope flushes pending write-backs to the host store first."""
        bk = self.backend
        if bk is not None:
            flush = getattr(bk, "plan_flush", None)
            if flush is not None:
                flush()
        return fn(*args)

    def run_ops(self, ops: List[Any], state: PlanState,
                round_idx: int) -> None:
        for op in ops:
            op.run(self, state, round_idx)


def execute_plan(sess, plan: StagePlan, *, carry=None,
                 state: Optional[Dict[str, Any]] = None) -> PlanResult:
    """Run `plan` against `sess` (the shared machinery behind
    ``Orchestrator.run_plan`` and ``GraphSession.run_plan``).

    When the session owns a store and its backend supports device-resident
    plan scopes (the jax backend), the whole program runs inside one scope:
    write-backs stay on device, the host copy is refreshed before any user
    callback and once at exit, and batch shapes are bucketed for re-jit
    avoidance. Cost reports are unaffected — they are computed host-side
    from the same inputs either way.
    """
    st = PlanState(state)
    st.carry = carry
    rn = _PlanRunner(sess)
    bk = rn.backend
    store = getattr(sess, "store", None)
    scoped = (store is not None and bk is not None
              and hasattr(bk, "begin_plan"))
    if scoped:
        bk.begin_plan(store)
    try:
        rn.run_ops(plan._ops, st, -1)
    finally:
        if scoped:
            bk.end_plan()
    return PlanResult(records=rn.records, loops=rn.loops, state=st)
