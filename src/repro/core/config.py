"""`SessionConfig`: one object for every session-construction option.

The front doors grew their options one kwarg at a time — `engine=`,
`backend=`, `kernel_backend=`, `replication=` (spelled `replicate=` on the
kvstore/graph doors), plus free-form engine opts — and each door re-declared
the set by hand. `SessionConfig` is the single consolidated surface:

    cfg = SessionConfig(engine="tdorch", backend="jax",
                        replication={"num_hot": 32},
                        elasticity=ElasticityConfig(migration=True))
    Orchestrator(store, config=cfg)
    DistributedHashTable(...).session(config=cfg)
    GraphSession(og, config=cfg)

Every door accepts the same `config=`; the old kwargs keep working through
`resolve_session_config`, whose `KWARG_ALIASES` table is the single source
of truth mapping legacy spellings onto config fields (this is where
`replicate=` and `replication=` are unified so the two can never drift
again). Passing a legacy kwarg that contradicts a non-default field of an
explicit `config=` raises — silent precedence is how drift starts.

This module is import-leaf on purpose (no core imports), so every layer —
engines, sessions, front doors — can depend on it without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

__all__ = ["SessionConfig", "KWARG_ALIASES", "resolve_session_config"]


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Everything that shapes an orchestration session, in one place.

    engine          scheduling strategy: "tdorch" (default) or a §2.3
                    baseline name ("push"/"pull"/"sort"), or a prebuilt
                    engine instance (shares its forest/backend caches).
    backend         numeric execution backend: None/"numpy" — the float64
                    oracle; "jax" — the jitted single-device pipeline;
                    "jax_spmd" — the mesh-sharded SPMD realization; or a
                    backend instance to share device caches.
    kernel_backend  fused-kernel dispatch on device backends
                    ("auto"/"fused"/"interpret"/"padded").
    replication     the adaptive hot-chunk subsystem
                    (`core/replication.py`): True / kwargs dict /
                    `ReplicationConfig` / a shared `HotChunkReplicator`.
    elasticity      the elastic-cluster subsystem (`core/elasticity.py`):
                    an `ElasticityConfig` (or kwargs dict) bundling
                    migration=, stealing=, recovery= — or a shared
                    `ElasticityManager`.
    engine_opts     extra engine-constructor kwargs (fanout=, C=, sigma=,
                    work_per_task=, ...), exactly what the legacy
                    `**engine_opts` tail carried.
    """

    engine: Any = "tdorch"
    backend: Any = None
    kernel_backend: Any = None
    replication: Any = None
    elasticity: Any = None
    engine_opts: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def replace(self, **kw) -> "SessionConfig":
        return dataclasses.replace(self, **kw)


# The single-source legacy-kwarg mapping table: old front-door spelling →
# `SessionConfig` field. Notably `replicate` (the kvstore/graph spelling)
# and `replication` (the core spelling) resolve to the same field here —
# adding a new session option means adding a config field plus one row.
KWARG_ALIASES: Dict[str, str] = {
    "engine": "engine",
    "backend": "backend",
    "kernel_backend": "kernel_backend",
    "replication": "replication",
    "replicate": "replication",  # legacy kvstore/graph spelling
    "elasticity": "elasticity",
}


def resolve_session_config(config=None, engine_opts: Dict[str, Any] | None
                           = None, **legacy) -> SessionConfig:
    """Merge an optional `config=` with legacy per-kwarg spellings into one
    resolved `SessionConfig`.

    Legacy kwargs use their OLD names (`KWARG_ALIASES` keys); None means
    "not passed" and defers to the config. A legacy value that contradicts a
    non-default field of an explicit `config=` raises `ValueError` (so do
    two aliases of the same field with different values). `engine_opts`
    merge over the config's, per key.
    """
    if config is not None and not isinstance(config, SessionConfig):
        if isinstance(config, dict):
            config = SessionConfig(**config)
        else:
            raise TypeError(
                f"config= must be a SessionConfig or kwargs dict, "
                f"got {type(config).__name__}")
    cfg = config if config is not None else SessionConfig()
    defaults = SessionConfig()
    updates: Dict[str, Any] = {}
    for kw, val in legacy.items():
        field = KWARG_ALIASES.get(kw)
        if field is None:
            raise TypeError(f"unknown session option {kw!r} "
                            f"(known: {sorted(KWARG_ALIASES)})")
        if val is None:
            continue
        current = getattr(cfg, field)
        if (config is not None and current != getattr(defaults, field)
                and current is not val and current != val):
            raise ValueError(
                f"session option {kw}={val!r} conflicts with "
                f"SessionConfig.{field}={current!r} — set it in one place")
        if field in updates and updates[field] != val:
            raise ValueError(
                f"conflicting spellings for SessionConfig.{field}: "
                f"{updates[field]!r} vs {val!r}")
        updates[field] = val
    if engine_opts:
        updates["engine_opts"] = {**cfg.engine_opts, **engine_opts}
    return dataclasses.replace(cfg, **updates) if updates else cfg
