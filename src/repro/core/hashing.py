"""Deterministic hashing used for data placement and transit-VM mapping.

The paper (§2.2) places each data chunk on a uniformly random machine to get
adversary-resistant load balance (Sanders' balls-into-bins argument), and maps
virtual transit machines VM(root, bfs_id) onto physical machines via a hash
known to every machine (Fig. 2 uses h(x, y) = (x + 3y) mod 8 + 1).

We use splitmix64 — a high-quality, stateless 64-bit mixer — so placement is
reproducible across hosts without any coordination (a requirement at
1000+-node scale: every worker must compute identical placement locally).
"""
from __future__ import annotations

import numpy as np

_U64 = np.uint64


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer. Input/output uint64."""
    x = np.asarray(x).astype(np.uint64, copy=True)
    with np.errstate(over="ignore"):
        x += _U64(0x9E3779B97F4A7C15)
        z = x
        z = (z ^ (z >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> _U64(27))) * _U64(0x94D049BB133111EB)
        z = z ^ (z >> _U64(31))
    return z


def hash_combine(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Combine two uint64 streams into one (order-sensitive)."""
    a = np.asarray(a).astype(np.uint64)
    b = np.asarray(b).astype(np.uint64)
    with np.errstate(over="ignore"):
        return splitmix64(a * _U64(0x9E3779B97F4A7C15) ^ splitmix64(b))


def chunk_home(keys: np.ndarray, num_machines: int, salt: int = 0) -> np.ndarray:
    """Random (hashed) home machine for each data chunk key (§2.2).

    Randomized placement is what makes Lemma 1 (weighted balls-into-bins)
    applicable: storage and *access* load are both balanced whp for any
    fixed (even adversarial) key distribution.
    """
    h = splitmix64(np.asarray(keys, dtype=np.uint64) + _U64(salt * 0x51ED2701 + 1))
    return (h % _U64(num_machines)).astype(np.int64)


def vm_to_pm(root: np.ndarray, node_id: np.ndarray, num_machines: int) -> np.ndarray:
    """Map virtual transit machine (root, bfs node id) -> physical machine.

    The tree root (node_id == 0) *is* the machine storing the chunk, per
    Fig. 2 ("a physical machine can simultaneously serve as both a leaf and
    an internal node"; the root of tree i is machine i). Interior nodes are
    hashed — the paper notes static transit choice + random chunk placement
    is equivalent to dynamic transit selection.
    """
    root = np.asarray(root, dtype=np.int64)
    node_id = np.asarray(node_id, dtype=np.int64)
    h = hash_combine(root.astype(np.uint64), node_id.astype(np.uint64) + _U64(1))
    pm = (h % _U64(num_machines)).astype(np.int64)
    return np.where(node_id == 0, root, pm)
