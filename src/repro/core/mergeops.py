"""Merge-able write-back operators (§3.4, Definition 2).

An operation ⊕ is merge-able iff there exist ⊙ and ⊗ with
    x ⊕ y₁ ⊕ … ⊕ yₙ = x ⊙ (y₁ ⊗ … ⊗ yₙ).
⊗ ("combine") pre-aggregates updates anywhere in the network — at execution
sites, at transit machines on the reverse meta-task tree, at forest nodes —
and ⊙ ("apply") touches the authoritative chunk exactly once. This is the
property that lets Phase 4 write-backs ride the tree without blowing up the
root's inbound traffic.

Updates are (rows, width) arrays. `combine_segments` performs the ⊗ reduction
over groups given by a segment id (rows pre-sorted not required).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class MergeOp:
    name: str
    # ⊗ : segment-combine updates. (values, segment_ids, num_segments, order)
    # `order` breaks ties deterministically (task priority / timestamp).
    combine_segments: Callable[[np.ndarray, np.ndarray, int, np.ndarray], np.ndarray]
    # ⊙ : apply combined update to stored value. (old, update) -> new
    apply: Callable[[np.ndarray, np.ndarray], np.ndarray]
    # identity element for ⊗ (used to mask absent segments)
    identity: float


def _seg_ufunc(ufunc, init):
    def combine(values, seg, nseg, order):
        out = np.full((nseg,) + values.shape[1:], init, dtype=values.dtype)
        ufunc.at(out, seg, values)
        return out

    return combine


def _seg_first_by_order(values, seg, nseg, order):
    """Deterministic 'one write wins': smallest `order` in each segment wins
    (Definition 2 case (iv): e.g. smallest timestamp / transaction id)."""
    # lexsort: primary seg, secondary order; first row of each segment wins.
    perm = np.lexsort((order, seg))
    seg_sorted = seg[perm]
    first = np.ones(len(perm), dtype=bool)
    first[1:] = seg_sorted[1:] != seg_sorted[:-1]
    out = np.zeros((nseg,) + values.shape[1:], dtype=values.dtype)
    out[seg_sorted[first]] = values[perm[first]]
    return out


_FMAX = np.finfo(np.float64).max


MERGE_OPS: Dict[str, MergeOp] = {
    # set-associative ⊕: ⊙ and ⊗ are both ⊕ (Definition 2 case (ii))
    "add": MergeOp(
        "add", _seg_ufunc(np.add, 0.0), lambda old, upd: old + upd, 0.0
    ),
    "min": MergeOp(
        "min", _seg_ufunc(np.minimum, _FMAX), np.minimum, _FMAX
    ),
    "max": MergeOp(
        "max", _seg_ufunc(np.maximum, -_FMAX), np.maximum, -_FMAX
    ),
    # idempotent ⊕ (case (i)): logical-or style flag writes
    "or": MergeOp(
        "or", _seg_ufunc(np.maximum, 0.0), np.maximum, 0.0
    ),
    # deterministic overwrite (case (iv)): lowest task priority wins
    "write": MergeOp(
        "write", _seg_first_by_order, lambda old, upd: upd, 0.0
    ),
}


def get_merge_op(name_or_op) -> MergeOp:
    if isinstance(name_or_op, MergeOp):
        return name_or_op
    try:
        return MERGE_OPS[name_or_op]
    except KeyError:
        raise KeyError(
            f"unknown merge op {name_or_op!r}; available: {sorted(MERGE_OPS)}"
        ) from None
