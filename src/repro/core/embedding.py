"""Skew-aware vocab embedding — the KV-store case study (§4) transplanted
into the LM stack.

Token-id frequency is Zipfian (the paper's hot-chunk regime verbatim). On
TPU, the standard vocab-parallel embedding's collective cost is dense
(a psum of the (T, d) output) and therefore *skew-independent* — so unlike
MoE dispatch, TD-Orch cannot reduce wire bytes here (DESIGN.md §4). What it
CAN reduce is the *memory-system* cost: Phase-1 contention detection keeps
the H hottest rows in a replicated cache (VMEM-resident on TPU, vs HBM
gathers for cold rows), so the gather stream touches HBM only for the
Zipf tail. This module implements that: exact results, hot-row hit-rate
reported, cache refreshed from the live histogram every `refresh` steps.

This module is a thin client of the session-level hot-chunk subsystem
(`core/replication.py`): the decayed-histogram election that picks the hot
rows is `replication.decayed_election` — the exact same electorate the
`Orchestrator` / `GraphSession` replica directories run — applied to a
replicated on-device cache instead of a machine bitmap.

.. deprecated::
    The standalone cache path (`init_cache` / `refresh_cache` keeping its
    own histogram) is superseded by `repro.paramserve.EmbeddingStore`: a
    replicating session owns ONE `HotChunkReplicator` directory (fed by
    Phase-1 contention detection, elected by the same `decayed_election`)
    and `EmbeddingStore.device_cache()` / `cache_from_replicator` export it
    as this module's `EmbedCache` view — one electorate, two realizations.
    `embed_skew_aware` itself (the jit-friendly device gather) stays.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .replication import decayed_election
from .spmd import detect_contention

_DEPRECATION = (
    "the standalone EmbedCache bookkeeping ({fn}) is deprecated: use "
    "repro.paramserve.EmbeddingStore with a replicating session — its "
    "device_cache() exports the session's shared HotChunkReplicator "
    "directory as the same EmbedCache view (see docs/paramserve.md)")


class EmbedCache(NamedTuple):
    hot_ids: jnp.ndarray  # (H,) row ids
    hot_rows: jnp.ndarray  # (H, d) replicated copies (VMEM-resident on TPU)
    lookup: jnp.ndarray  # (V,) -> cache slot or -1
    counts: jnp.ndarray  # (V,) running demand histogram (Phase 1 state)


def init_cache(table: jnp.ndarray, num_hot: int) -> EmbedCache:
    warnings.warn(_DEPRECATION.format(fn="init_cache"), DeprecationWarning,
                  stacklevel=2)
    V, d = table.shape
    return EmbedCache(
        hot_ids=jnp.zeros((num_hot,), jnp.int32),
        hot_rows=jnp.zeros((num_hot, d), table.dtype),
        lookup=jnp.full((V,), -1, jnp.int32),
        counts=jnp.zeros((V,), jnp.int32),
    )


def refresh_cache(table: jnp.ndarray, cache: EmbedCache,
                  decay: float = 0.5) -> EmbedCache:
    """Re-elect the hot set from the running histogram (Phase 2 pull: the
    elected rows are replicated). One `decayed_election` step of the shared
    subsystem; decay keeps the histogram adaptive."""
    warnings.warn(_DEPRECATION.format(fn="refresh_cache"),
                  DeprecationWarning, stacklevel=2)
    H = cache.hot_ids.shape[0]
    hot_ids, lookup, _valid, counts = decayed_election(
        cache.counts, H, decay=decay, min_count=1)
    hot_rows = table[hot_ids]
    return EmbedCache(hot_ids=hot_ids.astype(jnp.int32), hot_rows=hot_rows,
                      lookup=lookup, counts=counts)


def cache_from_replicator(table, replicator) -> EmbedCache:
    """Export a session's `HotChunkReplicator` directory as an `EmbedCache`.

    The replacement for the standalone `init_cache`/`refresh_cache` loop:
    the session already runs the decayed election (fed by Phase-1 contention
    detection on real request streams), so the device cache becomes a
    jit-friendly VIEW of that one electorate — `hot_ids` are the replicated
    chunks, `lookup` their directory slots, `counts` the live histogram.
    Rows of elected-but-out-of-range ids never occur (the electorate is over
    this table's chunk keys). `embed_skew_aware` consumes the result
    unchanged.
    """
    table = jnp.asarray(table)
    replicas = replicator.replicas
    hot_ids = jnp.asarray(replicas.hot_ids, dtype=jnp.int32)
    lookup = jnp.asarray(replicas.lookup, dtype=jnp.int32)
    counts = jnp.asarray(
        jnp.rint(jnp.asarray(replicator.counts)), dtype=jnp.int32)
    hot_rows = (table[hot_ids] if hot_ids.size
                else jnp.zeros((0, table.shape[1]), table.dtype))
    return EmbedCache(hot_ids=hot_ids, hot_rows=hot_rows, lookup=lookup,
                      counts=counts)


def embed_skew_aware(table: jnp.ndarray, ids: jnp.ndarray,
                     cache: EmbedCache,
                     axis_name: Optional[str] = None
                     ) -> Tuple[jnp.ndarray, EmbedCache, jnp.ndarray]:
    """Exact embedding lookup with hot-row caching.

    Returns (embeddings, updated cache (histogram accumulated), hit_rate).
    Cache hits read the replicated hot_rows buffer; misses gather from the
    (vocab-sharded) table. Results are exact either way — the cache only
    changes WHERE the bytes come from."""
    flat = ids.reshape(-1)
    counts = cache.counts + detect_contention(flat, cache.counts.shape[0],
                                              axis_name)
    slot = cache.lookup[flat]  # (T,) cache slot or -1
    hit = slot >= 0
    from_cache = cache.hot_rows[jnp.maximum(slot, 0)]
    from_table = jnp.take(table, flat, axis=0)
    out = jnp.where(hit[:, None], from_cache, from_table)
    hit_rate = hit.mean()
    out = out.reshape(*ids.shape, table.shape[1])
    return out, cache._replace(counts=counts), hit_rate
