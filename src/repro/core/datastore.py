"""Distributed data-chunk store (§2.2 "Data Storage").

Data are partitioned into chunks of B words; each chunk lives on a hashed
(≈ uniformly random) home machine. The store keeps the authoritative copy of
every chunk value plus the placement map. For the BSP simulator the values
live in one dense array indexed by chunk key; *placement* is what the cost
model charges against.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import hashing


@dataclasses.dataclass
class DataStore:
    """num_keys chunks, each `chunk_words` (=B) words wide, values float64.

    `home[k]` is the physical machine storing chunk k. Values are the
    authoritative copies; reads during a stage see the pre-stage snapshot
    (BSP semantics) and write-backs land once at the end of the stage.
    """

    values: np.ndarray  # (num_keys, value_width)
    home: np.ndarray  # (num_keys,) int64
    chunk_words: int  # B — words charged when a chunk moves
    P: int

    @staticmethod
    def create(
        num_keys: int,
        num_machines: int,
        value_width: int = 1,
        chunk_words: int | None = None,
        init: float = 0.0,
        salt: int = 0,
        dtype=np.float64,
    ) -> "DataStore":
        values = np.full((num_keys, value_width), init, dtype=dtype)
        home = hashing.chunk_home(np.arange(num_keys), num_machines, salt=salt)
        B = int(chunk_words) if chunk_words is not None else int(value_width)
        return DataStore(values=values, home=home, chunk_words=B, P=int(num_machines))

    @property
    def num_keys(self) -> int:
        return self.values.shape[0]

    @property
    def value_width(self) -> int:
        return self.values.shape[1]

    def snapshot(self) -> np.ndarray:
        return self.values.copy()

    def storage_per_machine(self) -> np.ndarray:
        out = np.zeros(self.P, dtype=np.int64)
        np.add.at(out, self.home, 1)
        return out


@dataclasses.dataclass
class TaskBatch:
    """A batch of lambda-tasks (Fig. 1), vectorized.

    Each task: reads chunk `read_keys[i]` (or none, -1), runs the stage's
    lambda on (context, read value), optionally writes back to
    `write_keys[i]` (default: same as read key). `origin[i]` is the machine
    initially holding the task; `ctx_words` = σ. `priority` resolves
    deterministic-overwrite races (Definition 2 case (iv)).
    """

    contexts: np.ndarray  # (n, ctx_width)
    read_keys: np.ndarray  # (n,) int64, -1 = no read
    origin: np.ndarray  # (n,) int64 machine ids
    write_keys: np.ndarray | None = None  # (n,) int64, -1 = no write
    priority: np.ndarray | None = None  # (n,) tie-break order
    ctx_words: int | None = None  # σ; defaults to ctx width

    def __post_init__(self):
        n = self.contexts.shape[0]
        self.read_keys = np.asarray(self.read_keys, dtype=np.int64)
        self.origin = np.asarray(self.origin, dtype=np.int64)
        if self.write_keys is None:
            self.write_keys = self.read_keys.copy()
        self.write_keys = np.asarray(self.write_keys, dtype=np.int64)
        if self.priority is None:
            self.priority = np.arange(n, dtype=np.int64)
        if self.ctx_words is None:
            self.ctx_words = int(self.contexts.shape[1]) if self.contexts.ndim > 1 else 1
        for arr, nm in [(self.read_keys, "read_keys"), (self.origin, "origin"),
                        (self.write_keys, "write_keys"), (self.priority, "priority")]:
            if arr.shape[0] != n:
                raise ValueError(f"{nm} length {arr.shape[0]} != n {n}")

    @property
    def n(self) -> int:
        return self.contexts.shape[0]

    @staticmethod
    def even_origins(n: int, num_machines: int) -> np.ndarray:
        """Round-robin initial task placement: Θ(n/P) per machine (§2.2)."""
        return np.arange(n, dtype=np.int64) % num_machines
