"""Distributed data-chunk store (§2.2 "Data Storage").

Data are partitioned into chunks of B words; each chunk lives on a hashed
(≈ uniformly random) home machine. The store keeps the authoritative copy of
every chunk value plus the placement map. For the BSP simulator the values
live in one dense array indexed by chunk key; *placement* is what the cost
model charges against.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import hashing


@dataclasses.dataclass
class DataStore:
    """num_keys chunks, each `chunk_words` (=B) words wide, values float64.

    `home[k]` is the physical machine storing chunk k. Values are the
    authoritative copies; reads during a stage see the pre-stage snapshot
    (BSP semantics) and write-backs land once at the end of the stage.
    """

    values: np.ndarray  # (num_keys, value_width)
    home: np.ndarray  # (num_keys,) int64
    chunk_words: int  # B — words charged when a chunk moves
    P: int
    # monotonic write counter: execution backends that keep a device-resident
    # copy of `values` (core/backend.py JaxBackend) key their cache on it, so
    # every mutation must go through write_rows()/touch()
    version: int = 0

    @staticmethod
    def create(
        num_keys: int,
        num_machines: int,
        value_width: int = 1,
        chunk_words: int | None = None,
        init: float = 0.0,
        salt: int = 0,
        dtype=np.float64,
    ) -> "DataStore":
        values = np.full((num_keys, value_width), init, dtype=dtype)
        home = hashing.chunk_home(np.arange(num_keys), num_machines, salt=salt)
        B = int(chunk_words) if chunk_words is not None else int(value_width)
        return DataStore(values=values, home=home, chunk_words=B, P=int(num_machines))

    @property
    def num_keys(self) -> int:
        return self.values.shape[0]

    @property
    def value_width(self) -> int:
        return self.values.shape[1]

    def write_rows(self, keys: np.ndarray, rows: np.ndarray) -> None:
        """Authoritative row update. The single mutation path all engines and
        loaders use — bumps `version` so device-side value caches invalidate
        (or incrementally apply) instead of serving stale chunks."""
        self.values[np.asarray(keys, dtype=np.int64)] = rows
        self.version += 1

    def touch(self) -> None:
        """Declare an out-of-band mutation of `values` (direct array writes
        by user code): invalidates any backend device cache."""
        self.version += 1

    def snapshot(self) -> np.ndarray:
        return self.values.copy()

    def storage_per_machine(self) -> np.ndarray:
        out = np.zeros(self.P, dtype=np.int64)
        np.add.at(out, self.home, 1)
        return out


@dataclasses.dataclass
class TaskBatch:
    """A batch of lambda-tasks (Fig. 1), vectorized — each task requesting
    *one or more* data items (§2.1).

    The canonical read layout is a CSR pair (`read_indptr`, `read_indices`):
    task i requests chunks `read_indices[read_indptr[i]:read_indptr[i+1]]`
    (possibly zero, possibly with duplicates). `read_keys` — a flat `(n,)`
    array with -1 meaning "no read" — is kept as a constructor convenience
    for arity-1 batches and remains available as a flat view whenever
    `max_arity <= 1` (it is None for genuinely ragged batches).

    Each task runs the stage's lambda on (context, gathered values),
    optionally writing back to `write_keys[i]` (default: same as the task's
    first read key). `origin[i]` is the machine initially holding the task;
    `ctx_words` = σ. `priority` resolves deterministic-overwrite races
    (Definition 2 case (iv)).
    """

    contexts: np.ndarray  # (n, ctx_width)
    read_keys: np.ndarray | None = None  # (n,) int64, -1 = no read (arity ≤ 1)
    origin: np.ndarray | None = None  # (n,) int64 machine ids
    write_keys: np.ndarray | None = None  # (n,) int64, -1 = no write
    priority: np.ndarray | None = None  # (n,) tie-break order
    ctx_words: int | None = None  # σ; defaults to ctx width
    read_indptr: np.ndarray | None = None  # (n+1,) CSR row pointers
    read_indices: np.ndarray | None = None  # (nnz,) requested chunk keys

    def __post_init__(self):
        n = self.contexts.shape[0]
        if self.origin is None:
            raise ValueError("TaskBatch needs `origin` machine ids")
        self.origin = np.asarray(self.origin, dtype=np.int64)

        if (self.read_indptr is None) != (self.read_indices is None):
            raise ValueError("read_indptr and read_indices must be given together")
        if self.read_indptr is not None:
            if self.read_keys is not None:
                raise ValueError("pass either read_keys or read_indptr/read_indices")
            self.read_indptr = np.asarray(self.read_indptr, dtype=np.int64)
            self.read_indices = np.asarray(self.read_indices, dtype=np.int64)
            if self.read_indptr.shape[0] != n + 1:
                raise ValueError(
                    f"read_indptr length {self.read_indptr.shape[0]} != n+1 {n + 1}")
            if self.read_indptr[0] != 0 or self.read_indptr[-1] != self.read_indices.shape[0]:
                raise ValueError("read_indptr must start at 0 and end at nnz")
            if (np.diff(self.read_indptr) < 0).any():
                raise ValueError("read_indptr must be non-decreasing")
            if self.read_indices.size and (self.read_indices < 0).any():
                raise ValueError("read_indices must be non-negative chunk keys")
            # flat convenience view exists only for arity-≤1 batches
            if self.max_arity <= 1:
                flat = np.full(n, -1, dtype=np.int64)
                has = np.diff(self.read_indptr) > 0
                flat[has] = self.read_indices
                self.read_keys = flat
        else:
            if self.read_keys is None:
                self.read_keys = np.full(n, -1, dtype=np.int64)
            self.read_keys = np.asarray(self.read_keys, dtype=np.int64)
            if self.read_keys.shape[0] != n:
                raise ValueError(f"read_keys length {self.read_keys.shape[0]} != n {n}")
            has = self.read_keys >= 0
            self.read_indptr = np.zeros(n + 1, dtype=np.int64)
            np.cumsum(has, out=self.read_indptr[1:])
            self.read_indices = self.read_keys[has].copy()

        if self.write_keys is None:
            self.write_keys = self.primary_read.copy()
        self.write_keys = np.asarray(self.write_keys, dtype=np.int64)
        if self.priority is None:
            self.priority = np.arange(n, dtype=np.int64)
        if self.ctx_words is None:
            self.ctx_words = int(self.contexts.shape[1]) if self.contexts.ndim > 1 else 1
        for arr, nm in [(self.origin, "origin"),
                        (self.write_keys, "write_keys"), (self.priority, "priority")]:
            if arr.shape[0] != n:
                raise ValueError(f"{nm} length {arr.shape[0]} != n {n}")

    @property
    def n(self) -> int:
        return self.contexts.shape[0]

    # ---- ragged-read geometry --------------------------------------------
    @property
    def arity(self) -> np.ndarray:
        """(n,) number of chunks each task requests."""
        return np.diff(self.read_indptr)

    @property
    def max_arity(self) -> int:
        return int(self.arity.max(initial=0))

    @property
    def nnz(self) -> int:
        """Total number of (task, requested-key) pairs."""
        return int(self.read_indices.shape[0])

    @property
    def pair_task(self) -> np.ndarray:
        """(nnz,) task index of each (task, key) pair, CSR order."""
        return np.repeat(np.arange(self.n, dtype=np.int64), self.arity)

    @property
    def primary_read(self) -> np.ndarray:
        """(n,) each task's first requested key (-1 if it reads nothing).

        The primary key is the one whose tree decides where the task
        executes and whose reverse meta-task tree same-key write-backs ride;
        secondary keys are gathered to the execution site.
        """
        out = np.full(self.n, -1, dtype=np.int64)
        has = self.arity > 0
        out[has] = self.read_indices[self.read_indptr[:-1][has]]
        return out

    @staticmethod
    def from_ragged(contexts, key_lists, origin, **kw) -> "TaskBatch":
        """Build a multi-get batch from per-task key sequences."""
        indptr = np.zeros(len(key_lists) + 1, dtype=np.int64)
        np.cumsum([len(k) for k in key_lists], out=indptr[1:])
        indices = (np.concatenate([np.asarray(k, dtype=np.int64) for k in key_lists])
                   if indptr[-1] else np.empty(0, dtype=np.int64))
        return TaskBatch(contexts=contexts, origin=origin,
                         read_indptr=indptr, read_indices=indices, **kw)

    @staticmethod
    def even_origins(n: int, num_machines: int) -> np.ndarray:
        """Round-robin initial task placement: Θ(n/P) per machine (§2.2)."""
        return np.arange(n, dtype=np.int64) % num_machines
