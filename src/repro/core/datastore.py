"""Distributed data-chunk store (§2.2 "Data Storage").

Data are partitioned into chunks of B words; each chunk lives on a hashed
(≈ uniformly random) home machine. The store keeps the authoritative copy of
every chunk value plus the placement map. For the BSP simulator the values
live in one dense array indexed by chunk key; *placement* is what the cost
model charges against.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import hashing


def stable_bucket_slots(bucket_ids: np.ndarray, num_buckets: int):
    """Each element's position within its bucket, preserving input order —
    the slotting rule shared by the shard-residency layout and the mesh
    task/pair placement (`core/shardexec.py`). Returns ``(slot, counts)``:
    element i lands at row ``slot[i]`` of bucket ``bucket_ids[i]``, whose
    total population is ``counts[bucket_ids[i]]``."""
    bucket_ids = np.asarray(bucket_ids, dtype=np.int64)
    counts = np.bincount(bucket_ids, minlength=num_buckets)
    order = np.argsort(bucket_ids, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = np.zeros(bucket_ids.size, dtype=np.int64)
    slot[order] = np.arange(bucket_ids.size, dtype=np.int64) \
        - starts[bucket_ids[order]]
    return slot, counts


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """Sharded-residency geometry: how the store's chunks partition over a
    device mesh whose shard m IS machine m (`core/shardexec.py`).

    Each shard materializes only the chunk rows it homes, as a dense
    (slab_rows, value_width) slab: chunk k lives on shard ``owner[k]`` at
    slab row ``local_slot[k]``; ``slab_keys[m, s]`` is the inverse map
    (padded with ``num_keys`` past machine m's last chunk). Pure placement
    metadata — the float values themselves are materialized per shard by
    the execution backend.
    """

    owner: np.ndarray  # (num_keys,) == DataStore.home
    local_slot: np.ndarray  # (num_keys,) row within the owner's slab
    slab_keys: np.ndarray  # (P, slab_rows) chunk key per slab row
    counts: np.ndarray  # (P,) chunks homed per machine

    @property
    def slab_rows(self) -> int:
        return int(self.slab_keys.shape[1])


@dataclasses.dataclass
class DataStore:
    """num_keys chunks, each `chunk_words` (=B) words wide, values float64.

    `home[k]` is the physical machine storing chunk k. Values are the
    authoritative copies; reads during a stage see the pre-stage snapshot
    (BSP semantics) and write-backs land once at the end of the stage.
    """

    values: np.ndarray  # (num_keys, value_width)
    home: np.ndarray  # (num_keys,) int64
    chunk_words: int  # B — words charged when a chunk moves
    P: int
    # monotonic write counter: execution backends that keep a device-resident
    # copy of `values` (core/backend.py JaxBackend) key their cache on it, so
    # every mutation must go through write_rows()/touch()
    version: int = 0

    @staticmethod
    def create(
        num_keys: int,
        num_machines: int,
        value_width: int = 1,
        chunk_words: int | None = None,
        init: float = 0.0,
        salt: int = 0,
        dtype=np.float64,
    ) -> "DataStore":
        values = np.full((num_keys, value_width), init, dtype=dtype)
        home = hashing.chunk_home(np.arange(num_keys), num_machines, salt=salt)
        B = int(chunk_words) if chunk_words is not None else int(value_width)
        return DataStore(values=values, home=home, chunk_words=B, P=int(num_machines))

    @property
    def num_keys(self) -> int:
        return self.values.shape[0]

    @property
    def value_width(self) -> int:
        return self.values.shape[1]

    def write_rows(self, keys: np.ndarray, rows: np.ndarray) -> None:
        """Authoritative row update. The single mutation path all engines and
        loaders use — bumps `version` so device-side value caches invalidate
        (or incrementally apply) instead of serving stale chunks."""
        self.values[np.asarray(keys, dtype=np.int64)] = rows
        self.version += 1

    def touch(self) -> None:
        """Declare an out-of-band mutation of `values` (direct array writes
        by user code): invalidates any backend device cache."""
        self.version += 1

    def rehome(self, keys: np.ndarray, new_home: np.ndarray) -> None:
        """Atomically move chunks to new home machines (live migration /
        shrink-mode recovery, `core/elasticity.py`).

        Mutates `home` IN PLACE — subsystems that alias the placement map
        (the replicator's `HotChunkReplicator.home`, a cached `ShardLayout`'s
        `owner`) see the move without re-plumbing — then drops the cached
        shard layout (its slot/slab geometry is stale) and bumps `version`
        so device-resident value/replica caches keyed on it rebuild against
        the new placement. Values are untouched: migration moves ownership,
        not data content.
        """
        keys = np.asarray(keys, dtype=np.int64)
        if keys.size == 0:
            return
        new_home = np.broadcast_to(
            np.asarray(new_home, dtype=np.int64).ravel(), keys.shape)
        if (new_home < 0).any() or (new_home >= self.P).any():
            raise ValueError(
                f"rehome targets must be machine ids in [0, {self.P})")
        self.home[keys] = new_home
        self.__dict__.pop("_shard_layout", None)
        self.version += 1

    def snapshot(self) -> np.ndarray:
        return self.values.copy()

    def shard_layout(self) -> ShardLayout:
        """The store's sharded-residency geometry (cached; `rehome()` is the
        one mutation path and drops the cache). Shard m's slab holds exactly
        the chunks with
        ``home == m``, in ascending key order; the padding rows that square
        the slabs off to the largest per-machine count are addressed by
        nobody (their key is ``num_keys``)."""
        lay = self.__dict__.get("_shard_layout")
        if lay is not None:
            return lay
        K, P = self.num_keys, self.P
        local_slot, counts = stable_bucket_slots(self.home, P)
        rows = max(int(counts.max(initial=1)), 1)
        slab_keys = np.full((P, rows), K, dtype=np.int64)
        slab_keys[self.home, local_slot] = np.arange(K, dtype=np.int64)
        lay = ShardLayout(owner=self.home, local_slot=local_slot,
                          slab_keys=slab_keys, counts=counts)
        self.__dict__["_shard_layout"] = lay
        return lay

    def storage_per_machine(self) -> np.ndarray:
        out = np.zeros(self.P, dtype=np.int64)
        np.add.at(out, self.home, 1)
        return out


@dataclasses.dataclass
class TaskBatch:
    """A batch of lambda-tasks (Fig. 1), vectorized — each task requesting
    *one or more* data items (§2.1).

    The canonical read layout is a CSR pair (`read_indptr`, `read_indices`):
    task i requests chunks `read_indices[read_indptr[i]:read_indptr[i+1]]`
    (possibly zero, possibly with duplicates). `read_keys` — a flat `(n,)`
    array with -1 meaning "no read" — is kept as a constructor convenience
    for arity-1 batches and remains available as a flat view whenever
    `max_arity <= 1` (it is None for genuinely ragged batches).

    Each task runs the stage's lambda on (context, gathered values),
    optionally writing back to `write_keys[i]` (default: same as the task's
    first read key). `origin[i]` is the machine initially holding the task;
    `ctx_words` = σ. `priority` resolves deterministic-overwrite races
    (Definition 2 case (iv)).
    """

    contexts: np.ndarray  # (n, ctx_width)
    read_keys: np.ndarray | None = None  # (n,) int64, -1 = no read (arity ≤ 1)
    origin: np.ndarray | None = None  # (n,) int64 machine ids
    write_keys: np.ndarray | None = None  # (n,) int64, -1 = no write
    priority: np.ndarray | None = None  # (n,) tie-break order
    ctx_words: int | None = None  # σ; defaults to ctx width
    read_indptr: np.ndarray | None = None  # (n+1,) CSR row pointers
    read_indices: np.ndarray | None = None  # (nnz,) requested chunk keys

    def __post_init__(self):
        n = self.contexts.shape[0]
        if self.origin is None:
            raise ValueError("TaskBatch needs `origin` machine ids")
        self.origin = np.asarray(self.origin, dtype=np.int64)

        if (self.read_indptr is None) != (self.read_indices is None):
            raise ValueError("read_indptr and read_indices must be given together")
        if self.read_indptr is not None:
            if self.read_keys is not None:
                raise ValueError("pass either read_keys or read_indptr/read_indices")
            self.read_indptr = np.asarray(self.read_indptr, dtype=np.int64)
            self.read_indices = np.asarray(self.read_indices, dtype=np.int64)
            if self.read_indptr.shape[0] != n + 1:
                raise ValueError(
                    f"read_indptr length {self.read_indptr.shape[0]} != n+1 {n + 1}")
            if self.read_indptr[0] != 0 or self.read_indptr[-1] != self.read_indices.shape[0]:
                raise ValueError("read_indptr must start at 0 and end at nnz")
            if (np.diff(self.read_indptr) < 0).any():
                raise ValueError("read_indptr must be non-decreasing")
            if self.read_indices.size and (self.read_indices < 0).any():
                raise ValueError("read_indices must be non-negative chunk keys")
            # flat convenience view exists only for arity-≤1 batches
            if self.max_arity <= 1:
                flat = np.full(n, -1, dtype=np.int64)
                has = np.diff(self.read_indptr) > 0
                flat[has] = self.read_indices
                self.read_keys = flat
        else:
            if self.read_keys is None:
                self.read_keys = np.full(n, -1, dtype=np.int64)
            self.read_keys = np.asarray(self.read_keys, dtype=np.int64)
            if self.read_keys.shape[0] != n:
                raise ValueError(f"read_keys length {self.read_keys.shape[0]} != n {n}")
            has = self.read_keys >= 0
            self.read_indptr = np.zeros(n + 1, dtype=np.int64)
            np.cumsum(has, out=self.read_indptr[1:])
            self.read_indices = self.read_keys[has].copy()

        if self.write_keys is None:
            self.write_keys = self.primary_read.copy()
        self.write_keys = np.asarray(self.write_keys, dtype=np.int64)
        if self.priority is None:
            self.priority = np.arange(n, dtype=np.int64)
        if self.ctx_words is None:
            self.ctx_words = int(self.contexts.shape[1]) if self.contexts.ndim > 1 else 1
        for arr, nm in [(self.origin, "origin"),
                        (self.write_keys, "write_keys"), (self.priority, "priority")]:
            if arr.shape[0] != n:
                raise ValueError(f"{nm} length {arr.shape[0]} != n {n}")

    @property
    def n(self) -> int:
        return self.contexts.shape[0]

    # ---- fail-fast validation --------------------------------------------
    def validate(self, store: "DataStore | None" = None, *,
                 num_keys: int | None = None,
                 num_machines: int | None = None) -> "TaskBatch":
        """Check the batch's CSR geometry and key/machine ranges, raising
        `ValueError` with an actionable message instead of letting a
        malformed batch surface as a cryptic numpy index error deep inside
        an engine. Called by `Orchestrator.run_stage` on every batch (cheap,
        vectorized); re-checks constructor invariants too, since the arrays
        are plain ndarrays a caller may have mutated since `__init__`.

        `store` (or explicit `num_keys`/`num_machines`) supplies the bounds;
        without either, only the store-independent geometry is checked.
        Returns the batch so call sites can chain it.
        """
        if store is not None:
            num_keys = store.num_keys if num_keys is None else num_keys
            num_machines = store.P if num_machines is None else num_machines
        n = self.n
        indptr, indices = self.read_indptr, self.read_indices
        if indptr.shape[0] != n + 1:
            raise ValueError(
                f"TaskBatch.read_indptr has {indptr.shape[0]} entries for a "
                f"batch of {n} tasks — a CSR row-pointer array needs n+1 "
                f"= {n + 1}")
        if indptr[0] != 0 or indptr[-1] != indices.shape[0]:
            raise ValueError(
                f"TaskBatch.read_indptr must run from 0 to nnz "
                f"({indices.shape[0]}), got [{indptr[0]} .. {indptr[-1]}] — "
                "the pointer array does not cover read_indices")
        steps = np.diff(indptr)
        if (steps < 0).any():
            t = int(np.flatnonzero(steps < 0)[0])
            raise ValueError(
                f"TaskBatch.read_indptr must be non-decreasing: task {t} has "
                f"indptr[{t}]={int(indptr[t])} > indptr[{t + 1}]="
                f"{int(indptr[t + 1])} — each task's key slice must follow "
                "the previous one")
        for arr, nm in [(self.origin, "origin"), (self.write_keys,
                        "write_keys"), (self.priority, "priority")]:
            if arr.shape[0] != n:
                raise ValueError(
                    f"TaskBatch.{nm} has {arr.shape[0]} entries for a batch "
                    f"of {n} tasks — every per-task array must have length n")
        if indices.size and (indices < 0).any():
            p = int(np.flatnonzero(indices < 0)[0])
            raise ValueError(
                f"TaskBatch.read_indices[{p}] = {int(indices[p])} is "
                "negative — requested chunk keys must be >= 0 (omit a task's "
                "reads by giving it an empty CSR slice, not a sentinel)")
        if (self.write_keys < -1).any():
            t = int(np.flatnonzero(self.write_keys < -1)[0])
            raise ValueError(
                f"TaskBatch.write_keys[{t}] = {int(self.write_keys[t])} is "
                "invalid — use -1 for 'writes nothing', >= 0 for a chunk key")
        if num_keys is not None:
            if indices.size and (indices >= num_keys).any():
                p = int(np.flatnonzero(indices >= num_keys)[0])
                raise ValueError(
                    f"TaskBatch.read_indices[{p}] = {int(indices[p])} is out "
                    f"of range for a store with {num_keys} chunks (task "
                    f"{int(np.searchsorted(indptr, p, side='right')) - 1})")
            if (self.write_keys >= num_keys).any():
                t = int(np.flatnonzero(self.write_keys >= num_keys)[0])
                raise ValueError(
                    f"TaskBatch.write_keys[{t}] = {int(self.write_keys[t])} "
                    f"is out of range for a store with {num_keys} chunks")
        if num_machines is not None and self.origin.size:
            bad = (self.origin < 0) | (self.origin >= num_machines)
            if bad.any():
                t = int(np.flatnonzero(bad)[0])
                raise ValueError(
                    f"TaskBatch.origin[{t}] = {int(self.origin[t])} is not a "
                    f"machine id in [0, {num_machines})")
        return self

    # ---- ragged-read geometry --------------------------------------------
    @property
    def arity(self) -> np.ndarray:
        """(n,) number of chunks each task requests."""
        return np.diff(self.read_indptr)

    @property
    def max_arity(self) -> int:
        return int(self.arity.max(initial=0))

    @property
    def nnz(self) -> int:
        """Total number of (task, requested-key) pairs."""
        return int(self.read_indices.shape[0])

    @property
    def pair_task(self) -> np.ndarray:
        """(nnz,) task index of each (task, key) pair, CSR order."""
        return np.repeat(np.arange(self.n, dtype=np.int64), self.arity)

    @property
    def primary_read(self) -> np.ndarray:
        """(n,) each task's first requested key (-1 if it reads nothing).

        The primary key is the one whose tree decides where the task
        executes and whose reverse meta-task tree same-key write-backs ride;
        secondary keys are gathered to the execution site.
        """
        out = np.full(self.n, -1, dtype=np.int64)
        has = self.arity > 0
        out[has] = self.read_indices[self.read_indptr[:-1][has]]
        return out

    @classmethod
    def concat(cls, batches, store: "DataStore | None" = None) -> "TaskBatch":
        """Merge ragged CSR batches into one, preserving order: batch j's
        tasks precede batch j+1's, CSR offsets are shifted onto one
        `read_indices` array, and priorities are rebased (order-preserving,
        per batch, each batch offset past the previous one) so Definition 2
        write races resolve exactly as "batch j before batch j+1, original
        order within each batch" — what a serving coalescer needs when it
        merges admission windows. Context widths and `ctx_words` must agree
        across batches. The result is `validate()`-checked (against `store`
        when given) before it is returned, so a bad offset surfaces here,
        not deep inside an engine."""
        batches = list(batches)
        if not batches:
            raise ValueError("TaskBatch.concat needs at least one batch")
        widths = {b.contexts.shape[1:] for b in batches}
        if len(widths) > 1:
            raise ValueError(
                f"TaskBatch.concat: context widths differ across batches "
                f"({sorted(widths)}) — coalesce only like-shaped tasks")
        sigmas = {int(b.ctx_words) for b in batches}
        if len(sigmas) > 1:
            raise ValueError(
                f"TaskBatch.concat: ctx_words differ across batches "
                f"({sorted(sigmas)})")
        indptr_parts, off = [batches[0].read_indptr], 0
        for b in batches[1:]:
            off += batches[len(indptr_parts) - 1].nnz
            indptr_parts.append(b.read_indptr[1:] + off)
        pr_parts, pr_off = [], 0
        for b in batches:
            p = np.asarray(b.priority, dtype=np.int64)
            if p.size:
                # order-preserving rebase: priorities are ordinal (lowest
                # wins), so only relative order within a batch is kept
                p = p - p.min() + pr_off
                pr_off = int(p.max()) + 1
            pr_parts.append(p)
        out = cls(
            contexts=np.concatenate([b.contexts for b in batches]),
            origin=np.concatenate([b.origin for b in batches]),
            write_keys=np.concatenate([b.write_keys for b in batches]),
            priority=np.concatenate(pr_parts),
            read_indptr=np.concatenate(indptr_parts),
            read_indices=np.concatenate([b.read_indices for b in batches]),
            ctx_words=batches[0].ctx_words,
        )
        return out.validate(store)

    @staticmethod
    def from_ragged(contexts, key_lists, origin, **kw) -> "TaskBatch":
        """Build a multi-get batch from per-task key sequences."""
        indptr = np.zeros(len(key_lists) + 1, dtype=np.int64)
        np.cumsum([len(k) for k in key_lists], out=indptr[1:])
        indices = (np.concatenate([np.asarray(k, dtype=np.int64) for k in key_lists])
                   if indptr[-1] else np.empty(0, dtype=np.int64))
        return TaskBatch(contexts=contexts, origin=origin,
                         read_indptr=indptr, read_indices=indices, **kw)

    @staticmethod
    def even_origins(n: int, num_machines: int) -> np.ndarray:
        """Round-robin initial task placement: Θ(n/P) per machine (§2.2)."""
        return np.arange(n, dtype=np.int64) % num_machines
