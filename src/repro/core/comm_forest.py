"""Communication forest (§3.1): P balanced F-ary trees, one rooted per machine.

Geometry only — message/merge semantics live in `engine.py`. Nodes use BFS
numbering with the root at index 0; children of node v are
F·v + 1 … F·v + F. The P leaves sit at depth `height` (the first P node
slots of that depth), one per physical machine. Interior (transit) virtual
machines are mapped to physical machines by `hashing.vm_to_pm`.

Fanout default follows the paper's theory-guided choice
F = Θ(log P / log log P) (§3.1, §3.5), clamped to ≥2.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import hashing


def theory_fanout(num_machines: int) -> int:
    """F = Θ(log P / log log P), the §3.5 setting; ≥2 always."""
    P = max(int(num_machines), 2)
    lp = math.log(max(P, 3))
    llp = math.log(max(lp, math.e ** 1.0))
    return max(2, int(round(lp / max(llp, 1e-9))))


@dataclasses.dataclass(frozen=True)
class CommForest:
    """Shared geometry of every tree in the forest (all P trees are congruent;
    only the root machine / transit hashing differs per tree)."""

    P: int
    F: int
    height: int  # leaf depth; phase 1 takes `height` BSP rounds (Fig. 2)

    @staticmethod
    def build(num_machines: int, fanout: int | None = None) -> "CommForest":
        P = int(num_machines)
        if P < 1:
            raise ValueError("need at least one machine")
        F = int(fanout) if fanout is not None else theory_fanout(P)
        F = max(2, F)
        height = 0
        while F**height < P:
            height += 1
        return CommForest(P=P, F=F, height=height)

    # -- node arithmetic (vectorized, BFS numbering, root = 0) -------------
    def first_at_depth(self, depth: int) -> int:
        # (F^d - 1) / (F - 1)
        return (self.F**depth - 1) // (self.F - 1)

    def leaf_node(self, machine: np.ndarray) -> np.ndarray:
        return self.first_at_depth(self.height) + np.asarray(machine, dtype=np.int64)

    def parent(self, node: np.ndarray) -> np.ndarray:
        node = np.asarray(node, dtype=np.int64)
        return np.where(node > 0, (node - 1) // self.F, 0)

    def physical(self, root_machine: np.ndarray, node: np.ndarray) -> np.ndarray:
        """Physical machine hosting VM(root, node)."""
        return hashing.vm_to_pm(root_machine, node, self.P)

    def leaf_machine_of(self, root_machine: np.ndarray, machine: np.ndarray) -> np.ndarray:
        """Leaves are identity-mapped: leaf m of every tree is machine m."""
        return np.asarray(machine, dtype=np.int64)
