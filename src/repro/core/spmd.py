"""TD-Orch, production SPMD realization (jax.shard_map over the device mesh).

This is the same four-phase structure as `engine.py`, re-architected for TPU
collectives (see DESIGN.md §3 — hardware adaptation):

  Phase 1 (contention detection): per-shard histogram of requested items +
    one `psum` — on TPU an all-reduce *is* the balanced aggregation tree the
    paper builds by hand, so counts ride it directly.
  Phase 2 (co-location):
    push — cold items' task payloads route to owner shards via a sorted,
    capacity-bounded `all_to_all` (the TPU-idiomatic form of message
    aggregation: static buffers play the meta-task level cap C);
    pull — the ≤H hottest items' *data* is replicated to every shard via a
    masked `psum` (the C-ary broadcast tree, realized as the bandwidth-
    optimal ring the hardware provides).
  Phase 3: local grouped compute (`lax.ragged_dot` here; the Pallas grouped
    GEMM in `repro.kernels.moe_gemm` on the optimized path).
  Phase 4: merge-able combine — weighted adds pre-combined on-shard (⊗)
    before the return `all_to_all`, applied once per output row (⊙).

The flagship application is MoE expert dispatch (tokens = tasks, experts =
data chunks, routing skew = data hot spots): `moe_push_pull` vs the two §2.3
baselines `moe_direct_push` (classic expert-parallel dispatch with capacity
drops) and `moe_direct_pull` (replicate every expert).

Everything is written per-shard (to be wrapped in shard_map); pass
``axis_name=None`` to run the identical code on one device (tests).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

# the four-phase device primitives live in core/jaxexec.py, shared with the
# jitted simulator backend (core/backend.py) and the mesh-sharded backend
# (core/shardexec.py) — re-exported here so the SPMD surface is unchanged.
# `detect_contention` (Phase 1: per-shard histogram + psum) used to carry a
# duplicate definition here; it is now the single jaxexec primitive.
from .jaxexec import (Routing, bucket_routing, contention_counts,  # noqa: F401
                      detect_contention, gather_from_buckets,
                      scatter_to_buckets, select_hot,
                      sort_by_group as _sort_by_group)


# ---------------------------------------------------------------------------
# grouped expert compute (Phase 3)
# ---------------------------------------------------------------------------
def grouped_swiglu(xs: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray,
                   group_sizes: jnp.ndarray, impl: str = "ragged",
                   capacity_mult: float = 2.0) -> jnp.ndarray:
    """Grouped SwiGLU FFN: xs (M, d) sorted by group; w_in (G, d, 2f),
    w_out (G, f, d).

    impl="ragged": lax.ragged_dot (exact; on backends without native
    support XLA lowers it DENSELY — every token × every expert — which the
    roofline's useful_ratio flags; the Pallas kernel in
    repro.kernels.moe_gemm is the tuned TPU form).

    impl="binned": capacity-binned batched GEMM (Switch-style): tokens
    scatter into (G, cap, d) bins, two (G,·,·)×(G,·,·) batched matmuls.
    FLOPs = cap·G ≈ capacity_mult·M — near-useful. Rows beyond a bin's
    capacity produce zeros (combine weights drop them); TD-Orch's hot-expert
    pull is precisely what keeps bins from overflowing under skew, which is
    what makes this MXU-friendly form safe (§Perf, pair C)."""
    if impl == "ragged":
        h = lax.ragged_dot(xs, w_in, group_sizes)
        gate, up = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(gate) * up
        return lax.ragged_dot(act, w_out, group_sizes)
    M, d = xs.shape
    G = w_in.shape[0]
    cap = max(8, int(capacity_mult * M / G))
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)])
    rows = jnp.arange(M, dtype=jnp.int32)
    gid = jnp.searchsorted(jnp.cumsum(group_sizes), rows, side="right"
                           ).astype(jnp.int32)
    gid = jnp.clip(gid, 0, G - 1)
    pos = rows - starts[gid]
    keep = (pos < cap) & (rows < jnp.sum(group_sizes))
    bins = jnp.zeros((G, cap, d), xs.dtype).at[
        jnp.where(keep, gid, G), jnp.where(keep, pos, 0)].set(
        xs, mode="drop")
    h = jnp.einsum("gcd,gdf->gcf", bins, w_in)
    gate, up = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu(gate) * up
    out_bins = jnp.einsum("gcf,gfd->gcd", act, w_out)
    out = out_bins[jnp.where(keep, gid, 0), jnp.where(keep, pos, 0)]
    return jnp.where(keep[:, None], out, 0.0)


# ---------------------------------------------------------------------------
# MoE dispatch engines
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEDispatchConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    num_hot: int = 0  # H: experts served by pull/replication (0 = pure push)
    hot_min_count: int = 1
    axis_name: Optional[str] = None
    ep_size: int = 1  # number of expert-parallel shards on axis_name
    gemm_impl: str = "ragged"  # ragged | binned (see grouped_swiglu)


class MoEAux(NamedTuple):
    dropped_assignments: jnp.ndarray  # scalar
    expert_counts: jnp.ndarray  # (E,) global demand (Phase-1 histogram)
    hot_ids: jnp.ndarray  # (H,) or (0,)


def _capacity(cfg: MoEDispatchConfig, num_tokens: int) -> int:
    # per-destination-shard send capacity for the all_to_all buffers
    per_shard = num_tokens * cfg.top_k / max(cfg.ep_size, 1)
    return max(8, int(per_shard * cfg.capacity_factor))


def moe_push_pull(
    x: jnp.ndarray,  # (T, d) local tokens
    topk_idx: jnp.ndarray,  # (T, k) expert assignment
    topk_gate: jnp.ndarray,  # (T, k) combine weights
    w_in: jnp.ndarray,  # (E_local, d, 2f)
    w_out: jnp.ndarray,  # (E_local, f, d)
    cfg: MoEDispatchConfig,
):
    """TD-Orch push-pull MoE dispatch (per-shard body).

    Cold experts: tokens pushed to the owner shard (all_to_all), computed
    there, pushed back, merge-combined. Hot experts: weights pulled
    (replicated via masked psum) and their tokens computed locally — no
    token ever crosses the network for a hot expert, and no capacity drop
    can hit it. This is exactly §3.3's decision rule with C→capacity.
    """
    T, d = x.shape
    k = cfg.top_k
    E, ep = cfg.num_experts, cfg.ep_size
    e_local = E // ep
    axis = cfg.axis_name
    my_shard = lax.axis_index(axis) if axis is not None else 0
    A = T * k
    flat_e = topk_idx.reshape(A).astype(jnp.int32)
    flat_g = topk_gate.reshape(A)
    token_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    # ---------------- Phase 1: contention detection -----------------------
    counts = detect_contention(flat_e, E, axis)

    y = jnp.zeros((T, d), dtype=x.dtype)

    # ---------------- pull path: hot experts ------------------------------
    if cfg.num_hot > 0:
        hot_ids, lookup, valid = select_hot(counts, cfg.num_hot,
                                            cfg.hot_min_count)
        H = cfg.num_hot
        # pull the hot experts' weights: every shard contributes the hot
        # experts it owns into a zero buffer; psum = C-ary broadcast tree
        local_eids = my_shard * e_local + jnp.arange(e_local)
        local_rank = lookup[local_eids]  # (E_local,) -1 if not hot
        contrib_mask = (local_rank >= 0)
        hot_w_in = jnp.zeros((H,) + w_in.shape[1:], w_in.dtype)
        hot_w_out = jnp.zeros((H,) + w_out.shape[1:], w_out.dtype)
        safe_rank = jnp.where(contrib_mask, local_rank, 0)
        hot_w_in = hot_w_in.at[safe_rank].add(
            jnp.where(contrib_mask[:, None, None], w_in, 0))
        hot_w_out = hot_w_out.at[safe_rank].add(
            jnp.where(contrib_mask[:, None, None], w_out, 0))
        if axis is not None:
            hot_w_in = lax.psum(hot_w_in, axis)
            hot_w_out = lax.psum(hot_w_out, axis)
        # local grouped compute over hot assignments
        assign_rank = lookup[flat_e]  # (A,) -1 = cold
        is_hot = assign_rank >= 0
        hot_sort_key = jnp.where(is_hot, assign_rank, H)
        order, sizes = _sort_by_group(hot_sort_key.astype(jnp.int32), H)
        xs = x[token_of[order]]
        out = grouped_swiglu(xs, hot_w_in, hot_w_out, sizes,
                             impl=cfg.gemm_impl)
        # Phase 4 (⊗ on-shard): weighted-add combine per token
        gates = jnp.where(is_hot, flat_g, 0.0)[order]
        y = y.at[token_of[order]].add(out * gates[:, None])
    else:
        hot_ids = jnp.zeros((0,), jnp.int32)
        is_hot = jnp.zeros((A,), bool)

    # ---------------- push path: cold experts -----------------------------
    cap = _capacity(cfg, T)
    owner = flat_e // e_local
    routing = bucket_routing(owner, ep, cap, active=~is_hot)
    send_x = scatter_to_buckets(x[token_of], routing, ep, cap)  # (ep,cap,d)
    meta = jnp.stack(
        [flat_e.astype(jnp.float32), jnp.ones((A,), jnp.float32)], axis=1)
    send_meta = scatter_to_buckets(meta, routing, ep, cap)  # (ep,cap,2)

    if axis is not None and ep > 1:
        recv_x = lax.all_to_all(send_x, axis, 0, 0)
        recv_meta = lax.all_to_all(send_meta, axis, 0, 0)
    else:
        recv_x, recv_meta = send_x, send_meta

    r_e = recv_meta[..., 0].astype(jnp.int32).reshape(ep * cap)
    r_valid = recv_meta[..., 1].reshape(ep * cap) > 0.5
    r_local = jnp.where(r_valid, r_e - my_shard * e_local, e_local)
    r_local = jnp.clip(r_local, 0, e_local)  # invalid -> sentinel group
    order2, sizes2 = _sort_by_group(r_local.astype(jnp.int32), e_local)
    xs2 = recv_x.reshape(ep * cap, d)[order2]
    out2 = grouped_swiglu(xs2, w_in, w_out, sizes2, impl=cfg.gemm_impl)
    inv2 = jnp.zeros_like(order2).at[order2].set(
        jnp.arange(order2.shape[0]))
    out2 = out2[inv2].reshape(ep, cap, d)

    if axis is not None and ep > 1:
        back = lax.all_to_all(out2, axis, 0, 0)
    else:
        back = out2
    y_assign = gather_from_buckets(back, routing, A)  # (A, d), original order
    cold_gate = jnp.where(is_hot | ~_kept_mask(routing), 0.0, flat_g)
    y = y.at[token_of].add(y_assign * cold_gate[:, None])

    dropped = jnp.sum((~is_hot) & ~_kept_mask(routing))
    if axis is not None:
        dropped = lax.psum(dropped, axis)
    return y, MoEAux(dropped_assignments=dropped, expert_counts=counts,
                     hot_ids=hot_ids)


def _kept_mask(routing: Routing) -> jnp.ndarray:
    """Per-assignment (original order) mask of slots that fit capacity."""
    inv = jnp.zeros_like(routing.order).at[routing.order].set(
        jnp.arange(routing.order.shape[0]))
    return routing.keep[inv]


def moe_direct_push(x, topk_idx, topk_gate, w_in, w_out,
                    cfg: MoEDispatchConfig):
    """§2.3 Direct Push baseline = classic expert parallelism: every token
    crosses to its expert's owner; hot experts overflow capacity and DROP."""
    cold_cfg = dataclasses.replace(cfg, num_hot=0)
    return moe_push_pull(x, topk_idx, topk_gate, w_in, w_out, cold_cfg)


def moe_direct_pull(x, topk_idx, topk_gate, w_in, w_out,
                    cfg: MoEDispatchConfig):
    """§2.3 Direct Pull baseline: replicate EVERY expert's weights to every
    shard (all_gather) and compute locally — no drops, but weight traffic is
    paid regardless of demand (prohibitive as E grows)."""
    E, ep = cfg.num_experts, cfg.ep_size
    axis = cfg.axis_name
    if axis is not None and ep > 1:
        all_w_in = lax.all_gather(w_in, axis, axis=0, tiled=True)
        all_w_out = lax.all_gather(w_out, axis, axis=0, tiled=True)
    else:
        all_w_in, all_w_out = w_in, w_out
    T, d = x.shape
    k = cfg.top_k
    A = T * k
    flat_e = topk_idx.reshape(A).astype(jnp.int32)
    flat_g = topk_gate.reshape(A)
    token_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order, sizes = _sort_by_group(flat_e, E)
    out = grouped_swiglu(x[token_of[order]], all_w_in, all_w_out, sizes,
                         impl=cfg.gemm_impl)
    y = jnp.zeros((T, d), x.dtype).at[token_of[order]].add(
        out * flat_g[order][:, None])
    counts = detect_contention(flat_e, E, axis)
    return y, MoEAux(dropped_assignments=jnp.zeros((), jnp.int32),
                     expert_counts=counts, hot_ids=jnp.zeros((0,), jnp.int32))


# ---------------------------------------------------------------------------
# dense reference (oracle; no distribution, no capacity)
# ---------------------------------------------------------------------------
def moe_reference(x, topk_idx, topk_gate, w_in_full, w_out_full):
    """Exact dense MoE: every assignment computed, no drops. Oracle for
    engine equivalence tests (w_*_full hold all E experts)."""
    T, d = x.shape
    k = topk_idx.shape[1]
    E = w_in_full.shape[0]
    A = T * k
    flat_e = topk_idx.reshape(A).astype(jnp.int32)
    flat_g = topk_gate.reshape(A)
    token_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order, sizes = _sort_by_group(flat_e, E)
    out = grouped_swiglu(x[token_of[order]], w_in_full, w_out_full, sizes)
    return jnp.zeros((T, d), x.dtype).at[token_of[order]].add(
        out * flat_g[order][:, None])
