"""Elastic sessions: live chunk migration, Phase-3 work stealing, and
stage-boundary failure recovery.

Replication (`core/replication.py`) copies hot chunks; this module is the
rest of the elasticity story the DPA-style load balancers need:

  * **`MigrationPlanner`** — live chunk re-homing. The planner keeps its own
    decayed per-(chunk, origin) demand histogram (fed by the same Phase-1
    request stream the replicator observes) and, every `refresh` stages,
    elects chunks whose sustained demand concentrates on one requesting
    machine: those chunks *move* (`DataStore.rehome`) to the dominant
    requester, charged as the dedicated ``migration`` phase (old home ships
    the chunk value to the new home, B+1 words — or a 1-word directory
    update when the target already holds a replica). Because `rehome`
    mutates `home` in place and bumps the store version, the replicator's
    aliased placement map, every engine's routing, and all three backends'
    device caches follow the move with no further plumbing.

  * **`WorkStealer`** — Phase-3 work stealing. After an engine's cost model
    assigns `exec_site`s, machines left holding more than
    ``ceil(threshold × mean)`` task tiles donate their highest-index tiles
    to under-loaded machines (deterministic greedy: most-loaded donors
    shed, least-loaded thieves fill). The move is charged under the
    ``phase3_steal`` phase — one (σ + value + header)-word message per
    stolen tile — *before* Phase-2 secondary forwarding, so a multi-get
    task's other values are forwarded straight to the thief. A
    `StragglerDetector` (or a dead machine in shrink-mode recovery) forces
    a machine's capacity to zero, draining it entirely. Stolen-task counts
    per machine surface in `SessionReport.per_machine()`.

  * **`RecoveryManager`** — stage-boundary failure recovery. A
    `FailureInjector` schedule (and/or a `HeartbeatMonitor`) declares
    machines dead at the start of a stage. BSP semantics mean no partial
    stage state exists: survivors are at the last stage boundary, and only
    the dead machine's homed chunks need restoring. The manager keeps a
    boundary snapshot every `checkpoint_every` stages — durably via
    `checkpoint/manager.py` when `directory=` is set, in-memory otherwise —
    plus a per-stage write-log, so the boundary value of every lost chunk is
    reconstructable exactly. Lost rows are genuinely clobbered and then
    restored (the recovery data path is exercised, not assumed); billing
    under the ``recovery`` phase distinguishes chunks re-derived from a
    surviving replica holder (peer send, B+1 words) from checkpoint-storage
    reads (`cost.ingress`, no in-mesh sender). Two modes:

      - ``on_failure="restart"`` (default): the machine is replaced in
        place — homes unchanged, lost chunks restored, and the interrupted
        stage replays from the boundary. Everything except the extra
        ``recovery`` phase is bit-identical to an uninterrupted run (final
        values AND per-phase cost signatures) — pinned by
        `tests/test_elastic.py`.
      - ``on_failure="shrink"``: the machine is gone for good. Its chunks
        re-home onto survivors (hashed placement over the shrunken fleet),
        future task origins remap off the dead machine, and work stealing
        drains any exec-site assignment that still lands there. Transit-VM
        hashing still maps over all P machines (a documented
        approximation — the forest is not re-built).

All three are deterministic, host-side control logic: numerics stay the
shared vectorized execute/apply pass, so elastic runs remain bit-identical
in *values* to inelastic ones, and cost parity across backends holds with
elasticity on (the simulation-fidelity contract of `core/engine.py`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import hashing
from .cost import (MIGRATION_PHASE, RECOVERY_PHASE, STEAL_PHASE,
                   CostAccumulator, StageReport)
from .datastore import DataStore, TaskBatch
from .replication import ReplicaSet
from ..runtime.failures import (FailureInjector, HeartbeatMonitor,
                                StragglerDetector)

__all__ = [
    "MigrationConfig", "StealConfig", "RecoveryConfig", "ElasticityConfig",
    "MigrationPlanner", "WorkStealer", "RecoveryManager",
    "ElasticityManager", "make_elasticity",
    "MIGRATION_PHASE", "STEAL_PHASE", "RECOVERY_PHASE",
]


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    """Knobs of live chunk re-homing (all deterministic).

    refresh    consider moves every `refresh` observed stages.
    decay      demand-histogram multiplier applied at each election.
    min_count  decayed demand a chunk needs to be a move candidate.
    max_moves  at most this many chunks move per election.
    affinity   share of a chunk's demand its dominant requesting machine
               must account for before the chunk moves there — below it,
               demand is diffuse and replication (not migration) is the
               right tool.
    imbalance  load guard: a move is skipped when it would push the target
               machine's homed-demand above `imbalance × mean`, unless the
               target is still lighter than the current home.
    """

    refresh: int = 4
    decay: float = 0.5
    min_count: float = 8.0
    max_moves: int = 16
    affinity: float = 0.5
    imbalance: float = 1.5


@dataclasses.dataclass(frozen=True)
class StealConfig:
    """Knobs of Phase-3 work stealing.

    threshold  donors are machines assigned more than ceil(threshold × mean)
               tiles; thieves fill up to floor(mean).
    min_tasks  batches smaller than this are never rebalanced (the fixed
               per-steal message cost isn't worth it).
    detector   optional `StragglerDetector` — machines it flags are treated
               as capacity-zero (hardware stragglers drain fully), on top
               of the data-skew histogram trigger.
    """

    threshold: float = 1.25
    min_tasks: int = 16
    detector: Optional[StragglerDetector] = None


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Knobs of stage-boundary failure recovery.

    injector          `FailureInjector` or its {stage: [machines]} schedule.
    monitor           optional `HeartbeatMonitor`; nodes it reports failed
                      are recovered exactly like injected deaths.
    checkpoint_every  boundary-snapshot period in stages; between snapshots
                      a per-stage write-log keeps restores exact.
    directory         durable checkpoints via `checkpoint/manager.py`
                      (atomic commit + integrity hash). None = in-memory
                      boundary snapshot (same recovery semantics, no disk).
    on_failure        "restart" — machine replaced in place, bit-identical
                      replay; "shrink" — machine permanently removed,
                      chunks/origins re-homed onto survivors.
    keep              durable checkpoint retention (forwarded to
                      `CheckpointManager`).
    """

    injector: object = None
    monitor: Optional[HeartbeatMonitor] = None
    checkpoint_every: int = 1
    directory: Optional[str] = None
    on_failure: str = "restart"
    keep: int = 3

    def __post_init__(self):
        if self.on_failure not in ("restart", "shrink"):
            raise ValueError(
                f"on_failure must be 'restart' or 'shrink', "
                f"got {self.on_failure!r}")


@dataclasses.dataclass(frozen=True)
class ElasticityConfig:
    """The one elasticity umbrella `SessionConfig.elasticity` carries.

    Each field accepts None/False (off), True (defaults), a kwargs dict, or
    the corresponding config instance. Shrink-mode recovery auto-enables
    stealing (a dead machine's exec-site assignments must drain somewhere).
    """

    migration: object = None  # None | True | dict | MigrationConfig
    stealing: object = None  # None | True | dict | StealConfig
    recovery: object = None  # None | True | dict | RecoveryConfig


def _coerce(spec, cls):
    if spec is None or spec is False:
        return None
    if spec is True:
        return cls()
    if isinstance(spec, cls):
        return spec
    if isinstance(spec, dict):
        return cls(**spec)
    raise TypeError(f"bad {cls.__name__} spec: {spec!r}")


# ---------------------------------------------------------------------------
# migration
# ---------------------------------------------------------------------------
class MigrationPlanner:
    """Elects and executes live chunk moves from sustained demand.

    Keeps a decayed per-(chunk, origin) request histogram. An election
    (every `refresh` observed stages) greedily walks move candidates in
    demand order: a chunk moves to its dominant requesting machine when
    that machine accounts for ≥ `affinity` of its demand, subject to the
    `imbalance` load guard and the `max_moves` cap. Executed moves go
    through `DataStore.rehome` — one atomic placement update every engine
    and backend observes — and are charged under the ``migration`` phase.
    """

    def __init__(self, store: DataStore,
                 config: Optional[MigrationConfig] = None):
        self.config = config or MigrationConfig()
        self.P = int(store.P)
        self.num_keys = int(store.num_keys)
        # (K, P) decayed demand split by requesting machine; its row sums
        # are the total-demand histogram the electorate ranks by
        self.by_origin = np.zeros((self.num_keys, self.P), dtype=np.float64)
        self.stage_idx = 0
        self._last_election = 0
        self.num_elections = 0
        self.num_migrations = 0  # chunks moved, cumulative
        self.moves: List[Tuple[int, int, int]] = []  # (key, old, new) log

    # ---- demand feed -----------------------------------------------------
    def observe(self, keys: np.ndarray, origins: np.ndarray) -> None:
        """Fold one stage's (requested key, requesting machine) pairs into
        the histogram. One call per stage."""
        keys = np.asarray(keys, dtype=np.int64)
        origins = np.asarray(origins, dtype=np.int64)
        if keys.size:
            np.add.at(self.by_origin, (keys, origins), 1.0)
        self.stage_idx += 1

    @property
    def due(self) -> bool:
        return self.stage_idx - self._last_election >= self.config.refresh

    # ---- election + charged move -----------------------------------------
    def maybe_migrate(self, store: DataStore,
                      replicas: Optional[ReplicaSet] = None
                      ) -> Optional[StageReport]:
        """Run an election if due. Returns the charged ``migration`` report
        when any chunk actually moved, None otherwise (not due, or the
        electorate produced no moves — the histogram still decays)."""
        if not self.due:
            return None
        cfg = self.config
        self._last_election = self.stage_idx
        self.num_elections += 1
        demand = self.by_origin.sum(axis=1)
        cand = np.flatnonzero(demand >= cfg.min_count)
        report = None
        if cand.size:
            report = self._execute(cand[np.argsort(-demand[cand],
                                                   kind="stable")],
                                   demand, store, replicas)
        self.by_origin *= cfg.decay
        return report

    def _execute(self, order, demand, store, replicas):
        cfg = self.config
        home = store.home
        # per-machine homed demand: the owner-load half of the election
        load = np.bincount(home, weights=demand, minlength=self.P)
        mean_load = max(float(load.mean()), 1e-12)
        keys: List[int] = []
        dsts: List[int] = []
        for k in order:
            row = self.by_origin[k]
            dst = int(np.argmax(row))
            src = int(home[k])
            d = float(demand[k])
            if dst == src or row[dst] < cfg.affinity * d:
                continue
            if (load[dst] + d > cfg.imbalance * mean_load
                    and load[dst] + d > load[src]):
                continue  # would make a strictly hotter spot elsewhere
                # (equal load is fine: the dominant requester's reads turn
                # local, a strict words win at the same balance)
            keys.append(int(k))
            dsts.append(dst)
            load[src] -= d
            load[dst] += d
            if len(keys) >= cfg.max_moves:
                break
        if not keys:
            return None
        keys_a = np.asarray(keys, dtype=np.int64)
        dst_a = np.asarray(dsts, dtype=np.int64)
        src_a = home[keys_a].copy()
        cost = CostAccumulator(self.P)
        cost.begin(MIGRATION_PHASE)
        # the move ships the chunk value (B+1 words) old→new home — unless
        # the new home already holds a replica of it, in which case only a
        # 1-word directory update travels (the copy is promoted in place)
        words = np.full(keys_a.size, store.chunk_words + 1, dtype=np.float64)
        if replicas is not None and replicas.hot_ids.size:
            words[replicas.holds(keys_a, dst_a)] = 1.0
        cost.send(src_a, dst_a, words)
        cost.work(dst_a, 1.0)
        cost.tick()
        cost.end()
        # atomic placement update: home mutates in place (replicator alias
        # stays coherent), shard layout + device caches invalidate
        store.rehome(keys_a, dst_a)
        self.num_migrations += keys_a.size
        self.moves.extend(zip(keys_a.tolist(), src_a.tolist(),
                              dst_a.tolist()))
        return cost.totals()


# ---------------------------------------------------------------------------
# work stealing
# ---------------------------------------------------------------------------
class WorkStealer:
    """Deterministic pre-Phase-3 task-tile rebalancer.

    `steal()` is called by an engine after `exec_site` assignment with an
    open ``phase3_steal`` phase: it plans donor→thief moves from the
    per-machine assignment histogram (plus straggler/dead-machine drains),
    charges one (σ + value + header)-word message per stolen tile, and
    returns the updated `exec_site`. The session drains `(src, dst)` pairs
    afterwards into `SessionReport.record_steals`.
    """

    def __init__(self, num_machines: int,
                 config: Optional[StealConfig] = None, *,
                 alive: Optional[np.ndarray] = None):
        self.config = config or StealConfig()
        self.P = int(num_machines)
        # shared, externally-owned liveness mask (shrink-mode recovery);
        # None = everything up
        self._alive = alive
        self.stolen_tasks = 0
        self.num_rebalances = 0
        self._pending: List[Tuple[np.ndarray, np.ndarray]] = []

    def bind_alive(self, alive: np.ndarray) -> None:
        self._alive = alive

    # ---- planning --------------------------------------------------------
    def plan(self, exec_site: np.ndarray,
             eligible: Optional[np.ndarray] = None
             ) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic steal plan: (stolen task indices, thief machine per
        stolen task). Empty when the batch is small or already balanced."""
        cfg = self.config
        n = int(exec_site.size)
        empty = (np.empty(0, dtype=np.int64),) * 2
        up = np.ones(self.P, dtype=bool) if self._alive is None \
            else np.asarray(self._alive, dtype=bool)
        drained = ~up
        if cfg.detector is not None:
            for m in cfg.detector.stragglers():
                if 0 <= int(m) < self.P:
                    drained[int(m)] = True
        if n < cfg.min_tasks and not drained.any():
            return empty
        counts = np.bincount(exec_site, minlength=self.P)
        healthy = ~drained
        n_healthy = max(int(healthy.sum()), 1)
        mean = n / n_healthy
        cap = np.where(healthy, math.ceil(cfg.threshold * mean), 0)
        surplus = np.maximum(counts - cap, 0)
        # skew balancing fills thieves to floor(mean) (never overfill past
        # balance); with a drained machine the thieves must absorb its WHOLE
        # assignment, so the fill target rounds up instead
        want = math.ceil(mean) if drained.any() else int(mean)
        deficit = np.where(healthy, np.maximum(want - counts, 0), 0)
        if surplus.sum() == 0 or deficit.sum() == 0:
            return empty
        # thief slots, least-loaded machines first (stable on machine id)
        thieves = np.flatnonzero(deficit > 0)
        thieves = thieves[np.argsort(counts[thieves], kind="stable")]
        slots = np.repeat(thieves, deficit[thieves])
        # donor tiles: per donor machine, its highest-index eligible tasks
        # — drained machines first, so slot truncation never strands a tile
        # on a dead/straggling donor in favor of a merely-hot one
        donors = np.flatnonzero(surplus > 0)
        donors = np.concatenate([donors[drained[donors]],
                                 donors[~drained[donors]]])
        parts: List[np.ndarray] = []
        for m in donors:
            cand = np.flatnonzero(exec_site == m) if eligible is None \
                else np.flatnonzero(eligible & (exec_site == m))
            take = min(int(surplus[m]), cand.size)
            if take:
                parts.append(cand[-take:])
        if not parts:
            return empty
        moved = np.concatenate(parts)
        k = min(moved.size, slots.size)
        return moved[:k], slots[:k]

    # ---- charged execution ----------------------------------------------
    def steal(self, tasks: TaskBatch, exec_site: np.ndarray,
              cost: CostAccumulator, *, value_width: int,
              eligible: Optional[np.ndarray] = None) -> np.ndarray:
        """Apply the plan inside an open ``phase3_steal`` phase: charge the
        tile moves, mutate a copy of `exec_site`, record the movement for
        the session's per-machine counters."""
        moved, dst = self.plan(exec_site, eligible)
        if moved.size == 0:
            return exec_site
        src = exec_site[moved].copy()
        exec_site = exec_site.copy()
        exec_site[moved] = dst
        # a stolen tile ships its σ-word context + (key, count) header, plus
        # the primary value already resident at the old site for readers
        has_read = tasks.arity[moved] > 0
        words = tasks.ctx_words + 2 + np.where(has_read, value_width, 0)
        cost.send(src, dst, words)
        cost.tick()
        self.note(src, dst)
        return exec_site

    def note(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Record a steal an engine charged itself (the push baseline's
        redirected-RPC model): counters + the session drain queue."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        self.stolen_tasks += int(src.size)
        self.num_rebalances += 1
        self._pending.append((src, dst))

    def drain(self) -> List[Tuple[np.ndarray, np.ndarray]]:
        """(src, dst) machine pairs of steals since the last drain — the
        session folds these into `SessionReport.record_steals`."""
        out, self._pending = self._pending, []
        return out


# ---------------------------------------------------------------------------
# failure recovery
# ---------------------------------------------------------------------------
class RecoveryManager:
    """Stage-boundary checkpoint/restore driven by injected/monitored
    failures. See the module docstring for the recovery semantics."""

    def __init__(self, store: DataStore,
                 config: Optional[RecoveryConfig] = None):
        self.config = config or RecoveryConfig()
        cfg = self.config
        self.P = int(store.P)
        inj = cfg.injector
        if isinstance(inj, dict):
            inj = FailureInjector(schedule={
                int(s): list(ms) for s, ms in inj.items()})
        self.injector = inj
        self.monitor = cfg.monitor
        self.alive = np.ones(self.P, dtype=bool)
        self.num_recoveries = 0  # machines recovered, cumulative
        self.chunks_restored = 0
        self._mgr = None
        if cfg.directory is not None:
            from ..checkpoint.manager import CheckpointManager
            self._mgr = CheckpointManager(cfg.directory, keep=cfg.keep)
        self._snap_stage = -1
        self._snap_values: Optional[np.ndarray] = None
        # write-log since the last snapshot: per stage, (written keys, their
        # post-stage rows) — replaying it over the snapshot reconstructs the
        # last stage boundary exactly
        self._log: List[Tuple[np.ndarray, np.ndarray]] = []
        self._seen_monitor: set = set()

    # ---- stage-boundary hook ---------------------------------------------
    def on_stage_start(self, stage: int, store: DataStore,
                       replicas: Optional[ReplicaSet] = None,
                       backend=None) -> Optional[StageReport]:
        """Take the boundary snapshot when due, then process any machines
        that died at this boundary. Returns the charged ``recovery`` report
        when a recovery ran, None otherwise."""
        cfg = self.config
        if (self._snap_stage < 0
                or stage - self._snap_stage >= max(cfg.checkpoint_every, 1)):
            self._snapshot(stage, store, backend)
        deaths: set = set()
        if self.injector is not None:
            deaths.update(int(m) for m in self.injector.tick(stage))
        if self.monitor is not None:
            fresh = set(self.monitor.failed_nodes()) - self._seen_monitor
            self._seen_monitor.update(fresh)
            deaths.update(int(m) for m in fresh)
        deaths = {m for m in deaths if 0 <= m < self.P and self.alive[m]}
        if not deaths:
            return None
        return self._recover(sorted(deaths), store, replicas, backend)

    def after_stage(self, tasks: TaskBatch, store: DataStore) -> None:
        """Append the stage's write-set rows to the boundary log (only
        needed between snapshots)."""
        if self.config.checkpoint_every <= 1:
            return
        wk = tasks.write_keys
        keys = np.unique(wk[wk >= 0])
        if keys.size:
            self._log.append((keys, store.values[keys].copy()))

    # ---- snapshot / reconstruct ------------------------------------------
    def _snapshot(self, stage: int, store: DataStore, backend=None) -> None:
        if backend is not None:
            backend.plan_flush()  # host copy must be current before we copy it
        if self._mgr is not None:
            self._mgr.save_async(stage, {"values": store.values,
                                         "home": store.home})
            self._mgr.wait()  # a boundary snapshot is a barrier, keep it exact
        else:
            self._snap_values = store.values.copy()
        self._snap_stage = stage
        self._log = []

    def _boundary_rows(self, keys: np.ndarray, store: DataStore) -> np.ndarray:
        """Reconstruct the last-stage-boundary value rows for `keys` from
        the snapshot plus the write-log — never from the live store."""
        if self._mgr is not None:
            restored = self._mgr.restore_latest(
                like={"values": store.values, "home": store.home})
            if restored is None:  # pragma: no cover - snapshot always taken
                raise RuntimeError("no checkpoint available for recovery")
            base = restored[1]["values"]
        else:
            base = self._snap_values
        rows = np.array(base[keys], dtype=store.values.dtype, copy=True)
        lookup = np.full(store.num_keys, -1, dtype=np.int64)
        lookup[keys] = np.arange(keys.size, dtype=np.int64)
        for lk, lrows in self._log:
            pos = lookup[lk]
            hit = pos >= 0
            if hit.any():
                rows[pos[hit]] = lrows[hit]
        return rows

    # ---- the recovery itself ---------------------------------------------
    def _recover(self, dead: List[int], store: DataStore,
                 replicas: Optional[ReplicaSet], backend=None) -> StageReport:
        cfg = self.config
        if backend is not None:
            backend.plan_flush()  # about to mutate store.values host-side
        cost = CostAccumulator(self.P)
        cost.begin(RECOVERY_PHASE)
        lost = np.flatnonzero(np.isin(store.home, dead))
        if lost.size:
            rows = self._boundary_rows(lost, store)
            # the loss is simulated for real: clobber, then restore through
            # the recovery data path — a restore bug cannot hide
            store.values[lost] = 0
            store.touch()
            if cfg.on_failure == "shrink":
                self.alive[dead] = False
                alive_ids = np.flatnonzero(self.alive)
                if alive_ids.size == 0:
                    raise RuntimeError("every machine is dead")
                targets = alive_ids[hashing.chunk_home(
                    lost, alive_ids.size, salt=self.num_recoveries + 1)]
            else:
                targets = store.home[lost].copy()  # replaced in place
            B = store.chunk_words
            # billing: replicated chunks with a surviving holder re-derive
            # from that peer (replicas never go stale — write-through); the
            # rest stream in from checkpoint storage (ingress, no sender)
            from_holder = np.zeros(lost.size, dtype=bool)
            donor = np.zeros(lost.size, dtype=np.int64)
            if replicas is not None and replicas.hot_ids.size:
                slot = replicas.lookup[lost]
                hit = np.flatnonzero(slot >= 0)
                if hit.size:
                    holders = replicas.holders[slot[hit]].copy()
                    holders[:, dead] = False
                    has = holders.any(axis=1)
                    from_holder[hit[has]] = True
                    donor[hit[has]] = np.argmax(holders[has], axis=1)
            if from_holder.any():
                cost.send(donor[from_holder], targets[from_holder], B + 1)
            if (~from_holder).any():
                cost.ingress(targets[~from_holder], B + 1)
            cost.work(targets, 1.0)
            cost.tick()
            store.write_rows(lost, rows)
            if cfg.on_failure == "shrink":
                store.rehome(lost, targets)
        elif cfg.on_failure == "shrink":
            self.alive[dead] = False
        self.num_recoveries += len(dead)
        self.chunks_restored += int(lost.size)
        cost.end()
        return cost.totals()

    # ---- shrink-mode batch adaptation ------------------------------------
    def adapt_batch(self, tasks: TaskBatch) -> TaskBatch:
        """Remap task origins off permanently-dead machines (shrink mode):
        deterministic round-robin over the survivors."""
        if self.alive.all():
            return tasks
        bad = ~self.alive[tasks.origin]
        if not bad.any():
            return tasks
        alive_ids = np.flatnonzero(self.alive)
        origin = tasks.origin.copy()
        origin[bad] = alive_ids[origin[bad] % alive_ids.size]
        return TaskBatch(
            contexts=tasks.contexts, origin=origin,
            write_keys=tasks.write_keys, priority=tasks.priority,
            ctx_words=tasks.ctx_words, read_indptr=tasks.read_indptr,
            read_indices=tasks.read_indices)


# ---------------------------------------------------------------------------
# the session-facing bundle
# ---------------------------------------------------------------------------
class ElasticityManager:
    """One object bundling the three elastic subsystems for a session.

    Shared across `Orchestrator.fork()` siblings exactly like the
    replicator: one demand histogram, one liveness mask, one stage clock.
    """

    def __init__(self, store: DataStore, config: ElasticityConfig):
        self.config = config
        self.P = int(store.P)
        mig = _coerce(config.migration, MigrationConfig)
        ste = _coerce(config.stealing, StealConfig)
        rec = _coerce(config.recovery, RecoveryConfig)
        if rec is not None and rec.on_failure == "shrink" and ste is None:
            ste = StealConfig()  # dead exec sites must drain somewhere
        self.planner = MigrationPlanner(store, mig) if mig else None
        self.recovery = RecoveryManager(store, rec) if rec else None
        self.stealer = WorkStealer(store.P, ste) if ste else None
        if self.stealer is not None and self.recovery is not None:
            self.stealer.bind_alive(self.recovery.alive)
        self.stage_idx = 0

    @property
    def alive(self) -> np.ndarray:
        return self.recovery.alive if self.recovery is not None \
            else np.ones(self.P, dtype=bool)

    def adapt_batch(self, tasks: TaskBatch) -> TaskBatch:
        return self.recovery.adapt_batch(tasks) \
            if self.recovery is not None else tasks

    def on_stage_start(self, store: DataStore, replicas, backend
                       ) -> List[StageReport]:
        """Recovery tick + migration election, in that order (a recovered
        store is what the election sees). Returns the charged reports of
        whatever actually happened this boundary."""
        reports: List[StageReport] = []
        if self.recovery is not None:
            rep = self.recovery.on_stage_start(self.stage_idx, store,
                                               replicas, backend)
            if rep is not None:
                reports.append(rep)
        if self.planner is not None:
            rep = self.planner.maybe_migrate(store, replicas)
            if rep is not None:
                reports.append(rep)
        return reports

    def observe(self, tasks: TaskBatch) -> None:
        if self.planner is not None:
            self.planner.observe(tasks.read_indices,
                                 tasks.origin[tasks.pair_task])

    def after_stage(self, tasks: TaskBatch, store: DataStore) -> None:
        if self.recovery is not None:
            self.recovery.after_stage(tasks, store)
        self.stage_idx += 1

    def counters(self) -> Dict[str, float]:
        """The elastic counters `serve.ServeStats` folds into its report."""
        out: Dict[str, float] = {}
        if self.planner is not None:
            out["migrations"] = self.planner.num_migrations
            out["migration_elections"] = self.planner.num_elections
        if self.stealer is not None:
            out["stolen_tasks"] = self.stealer.stolen_tasks
            out["steal_rebalances"] = self.stealer.num_rebalances
        if self.recovery is not None:
            out["recoveries"] = self.recovery.num_recoveries
            out["chunks_restored"] = self.recovery.chunks_restored
            out["machines_alive"] = int(self.recovery.alive.sum())
        return out


def make_elasticity(spec, store: DataStore) -> Optional[ElasticityManager]:
    """Coerce a user-facing `elasticity=` spec into a manager.

    None/False → off; an `ElasticityConfig` / kwargs dict → a fresh manager;
    an existing `ElasticityManager` is adopted as-is (shared state across
    forked sessions)."""
    if spec is None or spec is False:
        return None
    if isinstance(spec, ElasticityManager):
        return spec
    if isinstance(spec, dict):
        spec = ElasticityConfig(**spec)
    if not isinstance(spec, ElasticityConfig):
        raise TypeError(f"bad elasticity spec: {spec!r}")
    if spec.migration is None and spec.stealing is None \
            and spec.recovery is None:
        return None
    return ElasticityManager(store, spec)
