"""Shared jitted JAX execution primitives (device side of the four phases).

One home for the jnp/Pallas machinery that used to be duplicated between the
SPMD realization (`core/spmd.py`) and ad-hoc call sites: the Phase-1
contention histogram (dispatching to `repro.kernels.histogram`), the Phase-2
routing permutation (stable group sort + capacity-bounded bucket routing),
the Phase-3 padded gather + lambda, and the Phase-4 merge-able
segment-combine (dispatching to `repro.kernels.segment_combine`, Pallas on
TPU, jnp scatter fallback otherwise). `core/backend.py`'s `JaxBackend`
drives the simulator's numeric pass through these; `core/spmd.py` wraps the
same primitives in shard_map for the production MoE path — the two no
longer carry parallel implementations of top-k hot-set election or group
sorting.

Everything here is jit-compiled with **static shapes**: callers pass
fixed-size arrays (padded where the logical size is dynamic — writer lists
are padded to power-of-two buckets so similar batches share one compiled
executable) and out-of-range indices (`mode="drop"`) realize the padding: a
row that should not participate scatters to an out-of-range segment and
vanishes.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels.histogram.ops import count_ids
from ..kernels.segment_combine.ops import combine as _kernel_combine
from ..kernels.stage_fused.ops import fused_stage as _fused_stage

# order sentinel for rows excluded from a "write" (first-writer-wins) combine
_ORDER_MAX = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# Phase 1: contention histogram (kernels.histogram dispatch)
# ---------------------------------------------------------------------------
def contention_counts(ids, num_bins: int, weights=None, *,
                      kernel_backend: str = "auto"):
    """Per-id demand histogram. Unweighted counts ride the Pallas histogram
    kernel (`repro.kernels.histogram.count_ids`, jnp fallback off-TPU);
    weighted counts (meta-task multiplicities) use the same op's weighted
    path. Returns int32 counts of length `num_bins`."""
    return count_ids(jnp.asarray(ids), num_bins, weights=weights,
                     backend=kernel_backend)


def detect_contention(item_ids, num_items: int,
                      axis_name: str | None = None, weights=None, *,
                      kernel_backend: str = "auto") -> jnp.ndarray:
    """Global reference count per data item (§3.1) — the one Phase-1
    primitive every realization shares: a per-shard histogram
    (`contention_counts`) plus, under SPMD, one `psum` over `axis_name` —
    on TPU an all-reduce *is* the balanced aggregation tree the paper
    builds by hand, so counts ride it directly. `core/spmd.py` (MoE
    dispatch), `core/shardexec.py` (the mesh-sharded simulator backend) and
    `core/embedding.py` all call this same function; pass ``axis_name=None``
    for the single-device form."""
    counts = contention_counts(jnp.asarray(item_ids).reshape(-1), num_items,
                               weights=weights, kernel_backend=kernel_backend)
    if axis_name is not None:
        counts = lax.psum(counts, axis_name)
    return counts


def select_hot(counts: jnp.ndarray, num_hot: int, min_count: int = 1):
    """Top-`num_hot` items by demand, thresholded. Returns (hot_ids (H,),
    rank lookup (E,) with -1 = cold). Static H keeps shapes jit-stable —
    the SPMD analogue of the meta-task set's bounded size."""
    num_items = counts.shape[0]
    top_counts, hot_ids = lax.top_k(counts, num_hot)
    valid = top_counts >= min_count
    # invalid slots point at item 0 but are masked out of the lookup
    lookup = jnp.full((num_items,), -1, dtype=jnp.int32)
    ranks = jnp.arange(num_hot, dtype=jnp.int32)
    lookup = lookup.at[hot_ids].set(jnp.where(valid, ranks, -1), mode="drop")
    return hot_ids, lookup, valid


# ---------------------------------------------------------------------------
# Phase 2: routing permutations (stable sorts, capacity-bounded buckets)
# ---------------------------------------------------------------------------
def sort_by_group(ids: jnp.ndarray, num_groups: int):
    """Stable sort of assignments by group id; returns (order, group sizes).
    The routing permutation both the SPMD grouped compute and the jitted
    simulator backend use."""
    order = jnp.argsort(ids, stable=True)
    sizes = jnp.zeros(num_groups + 1, jnp.int32).at[ids].add(1)[:num_groups]
    return order, sizes


def inverse_permutation(order: jnp.ndarray) -> jnp.ndarray:
    return jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=order.dtype))


@jax.jit
def stable_argsort(keys: jnp.ndarray) -> jnp.ndarray:
    """Stable argsort — bit-identical permutation to numpy's stable argsort
    (stability pins the order of equal keys, so the two agree exactly)."""
    return jnp.argsort(keys, stable=True)


# ---------------------------------------------------------------------------
# Phase 4: merge-able segment combine (kernels.segment_combine dispatch)
# ---------------------------------------------------------------------------
def _segment_combine(updates, seg, num_segments: int, merge_name: str, order):
    """⊗-combine `updates` rows per segment. seg == num_segments drops the
    row (the static-shape form of "this row writes nothing"); output rows
    beyond the live segment count are garbage the caller slices off.

    ``add``/``min``/``max``/``or`` dispatch to
    `repro.kernels.segment_combine.combine` (Pallas on TPU for ``add``).
    ``write`` realizes Definition 2 case (iv) exactly like the numpy
    oracle — lowest `order` in the segment wins, ties broken by row
    position — as two 1-D scatter-mins plus a gather (no wide scatter).
    """
    n = updates.shape[0]
    if merge_name in ("add", "min", "max", "or"):
        return _kernel_combine(updates, seg, num_segments, op=merge_name)
    if merge_name == "write":
        segc = jnp.clip(seg, 0, max(num_segments - 1, 0))
        live = seg < num_segments
        win_ord = jnp.full(num_segments, _ORDER_MAX, order.dtype).at[seg].min(
            order, mode="drop")
        tied = live & (order == win_ord[segc])
        rows = jnp.arange(n, dtype=jnp.int32)
        win_row = jnp.full(num_segments, n, jnp.int32).at[
            jnp.where(tied, seg, num_segments)].min(rows, mode="drop")
        # the winning row per segment, gathered (rows of empty segments are
        # garbage — they sit beyond the live segment count)
        return updates[jnp.clip(win_row, 0, max(n - 1, 0))]
    raise KeyError(f"merge op {merge_name!r} has no jax combine")


def _as_update_rows(upd, n: int, dtype):
    """Normalize a lambda's "update" output to (n, w) rows (the same
    atleast_2d/transpose coercion the numpy apply path performs)."""
    u = jnp.atleast_2d(jnp.asarray(upd, dtype=dtype))
    if u.shape[0] != n:
        u = u.T
    return u


# ---------------------------------------------------------------------------
# Phase 3 + 4 fused: gather → lambda → writer-compact ⊗-combine, one dispatch
# ---------------------------------------------------------------------------
def _finish_stage(out, values, w_idx, seg, order, *, merge_name: str,
                  combine: bool, want_update: bool, want_result: bool):
    """Shared tail of the fused stage: coerce the lambda output, ⊗-combine
    the writer rows (compacted through `w_idx` so combine cost scales with
    writers, not batch size), and drop what the host did not ask for — XLA
    dead-code-eliminates everything feeding an unreturned output (with
    `want_result=False` the per-task results are never even computed, so a
    StagePlan round pays no result transfer at all)."""
    out = dict(out) if out is not None else {}
    upd = out.get("update")
    combined = None
    if combine and upd is not None:
        u = _as_update_rows(upd, values.shape[0], values.dtype)
        uw = u[jnp.clip(w_idx, 0, u.shape[0] - 1)]
        combined = _segment_combine(uw, seg, w_idx.shape[0], merge_name, order)
    return {"result": out.get("result") if want_result else None,
            "update": upd if want_update else None,
            "combined": combined}


@functools.partial(jax.jit, static_argnames=(
    "f", "fwd_mask", "merge_name", "combine", "want_update", "want_result"))
def run_stage_flat(values, keys, contexts, w_idx, seg, order, *, f,
                   fwd_mask: bool, merge_name: str, combine: bool,
                   want_update: bool, want_result: bool = True):
    """Arity-≤1 stage numerics: gather each task's chunk (zeros where it
    reads nothing), run the lambda, ⊗-combine its writers' updates.
    `w_idx` (B,) lists writer task rows padded with n to a bucket size B;
    `seg[j]` is writer j's write-segment id (B = dropped padding); `order`
    its priority for "write" merges."""
    has = keys >= 0
    gathered = jnp.where(has[:, None], values[jnp.clip(keys, 0)],
                         jnp.zeros((), values.dtype))
    out = f(contexts, gathered, has) if fwd_mask else f(contexts, gathered)
    return _finish_stage(out, gathered, w_idx, seg, order,
                         merge_name=merge_name, combine=combine,
                         want_update=want_update, want_result=want_result)


@functools.partial(jax.jit, static_argnames=(
    "f", "fwd_mask", "merge_name", "combine", "want_update", "want_result"))
def run_stage_ragged(values, read_indices, row, col, mask, contexts, w_idx,
                     seg, order, *, f, fwd_mask: bool, merge_name: str,
                     combine: bool, want_update: bool, want_result: bool = True):
    """Ragged (multi-get) stage numerics: padded `(n, max_arity, w)` gather
    plus validity mask, then lambda + writer ⊗-combine as in
    `run_stage_flat`."""
    n, A = mask.shape
    w = values.shape[1]
    gathered = jnp.zeros((n, A, w), values.dtype).at[row, col].set(
        values[read_indices], mode="drop")
    out = f(contexts, gathered, mask) if fwd_mask else f(contexts, gathered)
    return _finish_stage(out, gathered.reshape(n, A * w), w_idx, seg, order,
                         merge_name=merge_name, combine=combine,
                         want_update=want_update, want_result=want_result)


def run_stage_fused(values, indptr, indices, pair_task, contexts, seg,
                    order, *, num_segments: int, read_op: str, finish,
                    merge_name: str, combine: bool, want_update: bool,
                    want_result: bool = True, kernel_backend: str = "auto"):
    """Ragged-native stage numerics for a fused-able lambda
    (`core/fusedlam.FusedStageLambda`): gather → `read_op` reduction →
    `finish` → writer ⊗-combine straight off the CSR pair list, one
    `kernels.stage_fused` dispatch (Pallas on TPU, jnp fallback elsewhere,
    `"interpret"` for the device-free conformance pin) — no
    `(n, max_arity, w)` padding, no materialized intermediates. The CSR
    geometry arrays are *host* arrays here (the kernel's tiling is computed
    from them); `seg` is per-task with `num_segments` meaning "writes
    nothing". Same output contract as `run_stage_flat`/`run_stage_ragged`.
    """
    upd, combined = _fused_stage(
        values, indptr, indices, pair_task, contexts, seg, order,
        num_segments=num_segments, read_op=read_op, finish=finish,
        merge_name=merge_name, combine=combine, backend=kernel_backend)
    upd = upd.astype(values.dtype)
    if combined is not None:
        combined = combined.astype(values.dtype)
    return {"result": upd if want_result else None,
            "update": upd if want_update else None,
            "combined": combined}


# donate the store buffer into the ⊙-apply where the platform supports
# in-place donation (accelerators); CPU XLA would only log donation warnings
_APPLY_DONATE = () if jax.default_backend() == "cpu" else (0,)


@functools.partial(jax.jit, static_argnames=("merge_name",),
                   donate_argnums=_APPLY_DONATE)
def apply_rows(values, uniq_padded, combined, *, merge_name: str):
    """⊙-apply combined updates to the device-resident store copy.
    `uniq_padded` is the sorted written-key list padded with ascending
    out-of-range keys (dropped) — sorted *and* unique, which XLA's scatter
    exploits; `combined` rows align with it."""
    kw = dict(mode="drop", unique_indices=True, indices_are_sorted=True)
    if merge_name == "add":
        return values.at[uniq_padded].add(combined, **kw)
    if merge_name == "min":
        return values.at[uniq_padded].min(combined, **kw)
    if merge_name in ("max", "or"):
        return values.at[uniq_padded].max(combined, **kw)
    if merge_name == "write":
        return values.at[uniq_padded].set(combined, **kw)
    raise KeyError(f"merge op {merge_name!r} has no jax apply")


@functools.partial(jax.jit, static_argnames=("num_segments", "merge_name"))
def combine_dense(values, seg, *, num_segments: int, merge_name: str):
    """Dense segment combine over the full key range — the DistEdgeMap
    per-destination-vertex write-combine in one scatter."""
    return _segment_combine(values, seg, num_segments, merge_name,
                            jnp.zeros(values.shape[0], jnp.int32))


@jax.jit
def sorted_segment_sum(values, order, seg_ends):
    """Segment-sum via the cached Phase-2 routing permutation: permute rows
    into segment-contiguous order, prefix-sum, difference at segment
    boundaries. No scatter at all — this is the fast path for workloads that
    reuse one routing across stages (PageRank re-reduces the same edge set
    every round; the permutation is ingestion-time state, like the paper's
    destination trees). `seg_ends[i]` = last permuted row of segment i.
    Accuracy: sums are differences of a float32 prefix sum — absolute error
    is O(eps · total mass), which the backend's tolerance contract covers.
    """
    cs = jnp.cumsum(values[order], axis=0)
    ends = cs[seg_ends]
    return ends - jnp.concatenate([jnp.zeros_like(ends[:1]), ends[:-1]])


# ---------------------------------------------------------------------------
# capacity-bounded bucket routing (SPMD push path; shared with spmd.py)
# ---------------------------------------------------------------------------
class Routing(NamedTuple):
    order: jnp.ndarray  # sort order over assignments
    dest: jnp.ndarray  # destination bucket per sorted assignment
    pos: jnp.ndarray  # position within bucket per sorted assignment
    keep: jnp.ndarray  # fits under capacity


def bucket_routing(dest: jnp.ndarray, num_buckets: int, capacity: int,
                   active: jnp.ndarray) -> Routing:
    """Stable-sort assignments by destination bucket and compute each one's
    slot; slots ≥ capacity are dropped (push-side overflow — rare once the
    hot items are pulled instead, which is the point of push-pull)."""
    big = jnp.asarray(num_buckets, dest.dtype)
    key = jnp.where(active, dest, big)  # inactive rows sort to the end
    order = jnp.argsort(key, stable=True)
    key_sorted = key[order]
    # position within each bucket = index − start(bucket)
    counts = jnp.zeros(num_buckets + 1, jnp.int32).at[key_sorted].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(dest.shape[0], dtype=jnp.int32) - starts[key_sorted]
    keep = (key_sorted < num_buckets) & (pos < capacity)
    return Routing(order=order, dest=key_sorted, pos=pos, keep=keep)


def scatter_to_buckets(rows: jnp.ndarray, routing: Routing, num_buckets: int,
                       capacity: int, fill=0) -> jnp.ndarray:
    """(A, d) rows -> (num_buckets, capacity, d) send buffer."""
    d_shape = rows.shape[1:]
    buf = jnp.full((num_buckets, capacity) + d_shape, fill, dtype=rows.dtype)
    src = rows[routing.order]
    return buf.at[routing.dest, routing.pos].set(
        jnp.where(routing.keep.reshape((-1,) + (1,) * len(d_shape)), src, fill),
        mode="drop",
    )


def gather_from_buckets(buf: jnp.ndarray, routing: Routing,
                        num_assign: int) -> jnp.ndarray:
    """Inverse of scatter_to_buckets: (B, cap, d) -> (A, d) in original
    assignment order (dropped slots read back as zeros)."""
    d_shape = buf.shape[2:]
    got = buf[routing.dest, routing.pos]
    got = jnp.where(routing.keep.reshape((-1,) + (1,) * len(d_shape)), got, 0)
    return got[inverse_permutation(routing.order)]
